"""The fleet recovery loop: supervision for engines and the service.

Two supervisors share one failure model (fleet/health.py) and one fault
schedule (fleet/faults.py):

* ``IslandSupervisor`` — engine-level.  Hooks into the segment drivers
  through three optional callbacks (``drive_segments(supervisor=...)``
  and the mesh S2 round loop): a **supervised pull** that garbles/retries
  boundary reads and feeds the health detector, a **pre-dispatch** hook
  that injects scheduled delays, and a **boundary** hook that takes
  periodic host snapshots of island state and — on a death verdict —
  restores the last snapshot and replays.  Replay is exact: a carry is
  the island's complete search state and sampling is row-keyed
  prefix-stable, so re-running the lost segments regenerates the same
  generations the dead island computed (the mesh path re-lands them on a
  surviving device).
* ``FleetController`` — service-level.  Wraps a ``CampaignServer``: the
  server skips islands in ``server.down_islands`` and calls the
  controller's pull/delay hooks (``server.fleet``); the controller's
  ``step()`` applies due kills, converts health verdicts into failures,
  recovers a dead island's rows from the last on-disk snapshot (a
  PARTIAL ``checkpoint.store.restore`` — only the dead island's subtree
  is read), re-places them on surviving islands through the existing
  allocator (degraded mode; unplaceable rows park until a slot or the
  island returns), re-admits returning islands, and schedules
  ``repack``-based lane rebalancing when slot-occupancy skew between
  islands exceeds a threshold — the same relocation mechanism recovery
  uses, on a second trigger.

Rows recovered from a snapshot resume bit-exactly (same state, same
keys); a row that was admitted after the last snapshot replays from its
request, which is equally deterministic (admission state is a pure
function of the request).  Either way the final ``IPOPResult`` matches
the fault-free run — the chaos gate in benchmarks/bench_service.py and
tests/test_fleet.py assert it.

Zero-overhead contract: nothing in the engines or the server imports
this module; with no supervisor installed every hook site is a single
host-side ``is None`` check (no device syncs, no extra programs —
pinned in tests/test_obs.py and tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro import obs
from repro.checkpoint import store
from repro.fleet.faults import FaultPlan
from repro.fleet.health import FleetHealth, HealthConfig
from repro.obs.recorder import recorder as flight_recorder


@dataclasses.dataclass
class FleetConfig:
    """User surface of fleet supervision (``run_ipop(fleet=...)``,
    ``serve_campaigns --fleet``)."""

    snapshot_every: int = 4          # boundaries between snapshots
    plan: Optional[FaultPlan] = None  # injected chaos schedule (tests/bench)
    deadline_s: float = 30.0         # health: boundary-pull deadline
    stall_boundaries: int = 3        # health: no-progress boundaries → dead
    retries: int = 2                 # suspect pulls before dead; garbled-pull
    backoff_s: float = 0.0           # re-reads share the same retry budget
    skew_threshold: float = 0.5      # occupancy-fraction skew → lane repack
    postmortem_dir: Optional[str] = None  # flight-recorder dump directory

    def health_config(self) -> HealthConfig:
        return HealthConfig(deadline_s=self.deadline_s,
                            stall_boundaries=self.stall_boundaries,
                            retries=self.retries, backoff_s=self.backoff_s)


def _host(tree):
    return jax.tree_util.tree_map(np.asarray, tree)


class IslandSupervisor:
    """Engine-level supervision: snapshot / fault / health hooks for the
    bucketed segment driver (one island) and the mesh S2 round loop (one
    island per shard)."""

    def __init__(self, cfg: Optional[FleetConfig] = None):
        self.cfg = cfg or FleetConfig()
        self.plan = self.cfg.plan
        self.health = FleetHealth(self.cfg.health_config())
        self._dispatched: set = set()   # islands with a segment in flight
        self._snap: Dict[int, dict] = {}
        self._statics: Dict[int, dict] = {}
        self._shard_dev: Dict[int, object] = {}
        self._dead_devs: set = set()
        if self.cfg.postmortem_dir:
            flight_recorder().out_dir = self.cfg.postmortem_dir

    # -- shared hooks (service + both engine drivers) -----------------------

    def pull(self, island: int, boundary: int, fn):
        """Supervised boundary pull: apply scheduled corruption, retry
        implausible (non-monotone budget) reads, grade health."""
        t0 = time.perf_counter()
        k_idx, active, fevals, best_f = fn()
        if self.plan is not None and self.plan.corrupts(island, boundary):
            fevals = np.zeros_like(fevals)      # garbled read, fired once
        fev = float(np.sum(fevals))
        tries = 0
        while fev < self.health.last_fev(island) \
                and tries < max(1, self.cfg.retries):
            # budget counters are monotone by construction: a regressing
            # sum can only be a corrupt read — re-pull, with backoff
            tries += 1
            obs.metrics().counter("fleet_pull_retries_total",
                                  island=island).inc()
            if self.cfg.backoff_s:
                time.sleep(self.cfg.backoff_s * tries)
            k_idx, active, fevals, best_f = fn()
            fev = float(np.sum(fevals))
        expect = island in self._dispatched
        self._dispatched.discard(island)
        wall = time.perf_counter() - t0
        self.health.observe(island, boundary, fev, wall,
                            expect_progress=expect)
        # flight-recorder feed: host scalars already pulled, nothing new
        flight_recorder().observe(island, boundary, wall=round(wall, 6),
                                  fevals=fev,
                                  grade=self.health.state(island))
        return k_idx, active, fevals, best_f

    def before_dispatch(self, island: int, boundary: int):
        """Pre-dispatch hook: injected delay faults + the progress-expected
        marker the stall detector keys on."""
        if self.plan is not None:
            d = self.plan.delay(island, boundary)
            if d:
                time.sleep(d)
        self._dispatched.add(island)

    # -- bucketed drive_segments (single island 0) --------------------------

    def segment_boundary(self, b: int, carry, n_traces: int):
        """Called at the top of every ``drive_segments`` iteration; returns
        ``(carry, n_traces_to_keep, recovered)``.  On a death verdict the
        last snapshot's carry is restored and the trace list truncated to
        the snapshot point — replay regenerates the rest identically."""
        ev = self.plan.kill_at(0, b) if self.plan is not None else None
        if ev is not None or self.health.is_dead(0):
            reason = ("killed" if ev is not None
                      else self.health.island(0).reason or "deadline")
            snap = self._snap.get(0)
            if snap is None:
                raise RuntimeError(
                    f"island died at boundary {b} before the first snapshot")
            t0 = time.perf_counter()
            reg = obs.metrics()
            reg.counter("fleet_failures_total", reason=reason).inc()
            rec = flight_recorder()
            rec.observe(0, b, event="fault", grade="dead", reason=reason)
            rec.dump(0, b, "dead", extra={"reason": reason, "mode": "replayed",
                                          "snapshot_boundary": snap["boundary"]})
            lost = max(0.0, self.health.last_fev(0) - snap["fev"])
            with obs.tracer().span("recover", island=0, boundary=b,
                                   reason=reason, mode="replayed"):
                carry = jax.device_put(snap["carry"])
                self.health.revive(0, b)
                self.health.reset_progress(0, snap["fev"])
                self._dispatched.discard(0)
            reg.counter("fleet_recoveries_total", mode="replayed").inc()
            reg.histogram("fleet_recovery_wall_s").observe(
                time.perf_counter() - t0)
            reg.histogram("fleet_lost_work_evals").observe(lost)
            return carry, snap["n_traces"], True
        if self.cfg.snapshot_every and b % self.cfg.snapshot_every == 0:
            self._snap[0] = {"carry": _host(carry),
                             "n_traces": int(n_traces),
                             "fev": self.health.last_fev(0), "boundary": b}
        return carry, n_traces, False

    # -- mesh S2 round loop (one island per shard) --------------------------

    def mesh_init(self, shards: List[dict], devs: List):
        """Record the per-shard static operands (keys/instances never change
        mid-campaign) and take snapshot 0."""
        for s, sh in enumerate(shards):
            self._statics[s] = {
                "keys": np.asarray(sh["keys"]),
                "insts": (None if sh["insts"] is None
                          else _host(sh["insts"])),
            }
            self._shard_dev[s] = devs[s % len(devs)]
        self._mesh_snapshot(0, shards)

    def _mesh_snapshot(self, rnd: int, shards: List[dict]):
        for s, sh in enumerate(shards):
            if sh["done"] and s in self._snap:
                continue                # final state already captured
            self._snap[s] = {
                "carry": _host(sh["carry"]),
                "traces": [_host(t) for t in sh["traces"]],
                "segments": list(sh["segments"]),
                "done": sh["done"], "best": sh["best"],
                "fevals": sh["fevals"],
                "fev": self.health.last_fev(s), "boundary": rnd,
            }

    def mesh_round(self, rnd: int, shards: List[dict], devs: List):
        """Called at the top of every S2 round: apply due kills, convert
        health verdicts, take the periodic snapshot."""
        if self.plan is not None:
            for ev in self.plan.kills_at(rnd):
                if ev.island < len(shards):
                    self._mesh_kill(ev.island, rnd, shards, devs, "killed")
        for s in range(len(shards)):
            if self.health.is_dead(s):
                self._mesh_kill(s, rnd, shards, devs,
                                self.health.island(s).reason or "deadline")
        if self.cfg.snapshot_every and rnd \
                and rnd % self.cfg.snapshot_every == 0:
            self._mesh_snapshot(rnd, shards)

    def _replacement_device(self, s: int, devs: List):
        """Next device after the dead one, skipping known-dead devices;
        falls back to the dead device itself when no healthy device is left
        (simulated faults: the hardware is actually fine)."""
        old = self._shard_dev[s]
        self._dead_devs.add(old)
        start = devs.index(old) if old in devs else s
        for off in range(1, len(devs) + 1):
            cand = devs[(start + off) % len(devs)]
            if cand not in self._dead_devs:
                return cand
        return old

    def _mesh_kill(self, s: int, rnd: int, shards: List[dict], devs: List,
                   reason: str):
        snap = self._snap.get(s)
        if snap is None:
            raise RuntimeError(
                f"island {s} died at round {rnd} before the first snapshot")
        t0 = time.perf_counter()
        reg = obs.metrics()
        reg.counter("fleet_failures_total", reason=reason).inc()
        rec = flight_recorder()
        rec.observe(s, rnd, event="fault", grade="dead", reason=reason)
        rec.dump(s, rnd, "dead", extra={"reason": reason, "mode": "replayed",
                                        "snapshot_boundary": snap["boundary"]})
        lost = max(0.0, self.health.last_fev(s) - snap["fev"])
        with obs.tracer().span("recover", island=s, boundary=rnd,
                               reason=reason, mode="replayed"):
            dev = self._replacement_device(s, devs)
            sh, stat = shards[s], self._statics[s]
            sh["keys"] = jax.device_put(stat["keys"], dev)
            sh["insts"] = (None if stat["insts"] is None
                           else jax.device_put(stat["insts"], dev))
            sh["carry"] = jax.device_put(snap["carry"], dev)
            sh["traces"] = list(snap["traces"])  # host trees; assembly is host
            sh["segments"] = list(snap["segments"])
            sh["done"], sh["best"] = snap["done"], snap["best"]
            sh["fevals"] = snap["fevals"]
            self._shard_dev[s] = dev
            self.health.revive(s, rnd)
            self.health.reset_progress(s, snap["fev"])
            self._dispatched.discard(s)
        reg.counter("fleet_recoveries_total", mode="replayed").inc()
        reg.histogram("fleet_recovery_wall_s").observe(
            time.perf_counter() - t0)
        reg.histogram("fleet_lost_work_evals").observe(lost)


# ---------------------------------------------------------------------------
# service-level controller
# ---------------------------------------------------------------------------

def _rows_regressed(prev, jobs, fevals) -> bool:
    """True when some row STILL HOLDING the job it held at the last pull
    reads fewer evaluations — impossible for a monotone counter, so it can
    only be a garbled read.  Rows whose job changed (retired + re-used
    slot) are excluded: their reset-to-zero is legitimate."""
    if prev is None:
        return False
    pjobs, pfev = prev
    same = (pjobs == jobs) & (jobs >= 0)
    return bool(np.any(np.asarray(fevals)[same] < pfev[same]))


def _rows_advanced(prev, jobs, fevals) -> bool:
    """True when the island did real work since the last pull: a same-job
    row's counter advanced, or a freshly admitted row (job changed since
    the last pull) evaluated anything at all."""
    if prev is None:
        return True                     # first pull: nothing to compare
    pjobs, pfev = prev
    fevals = np.asarray(fevals)
    same = (pjobs == jobs) & (jobs >= 0)
    fresh = (pjobs != jobs) & (jobs >= 0)
    return bool(np.any(fevals[same] > pfev[same])
                or np.any(fevals[fresh] > 0))


def occupancy_counts(al) -> List[int]:
    """Occupied rows per island of one lane's allocator."""
    return [al.rows_per_island - al.free_rows(i)
            for i in range(al.n_islands)]


def occupancy_skew(al) -> float:
    """Max-min occupied-fraction spread across one lane's islands — the
    ``service_slot_occupancy`` skew the rebalance trigger is written
    against."""
    counts = occupancy_counts(al)
    return (max(counts) - min(counts)) / al.rows_per_island


class FleetController:
    """Fault-tolerant supervision loop around a ``CampaignServer``.

    Install by construction: ``ctl = FleetController(server, config)``;
    then drive the service through ``ctl.step()`` / ``ctl.drain()``
    instead of the server's own.  The controller owns the snapshot
    cadence (through the server's auto-snapshot path), fault application,
    health verdicts, row recovery and skew rebalancing; the server only
    carries two passive hook points (``down_islands`` and the
    ``fleet.pull`` / ``fleet.before_dispatch`` callbacks).
    """

    def __init__(self, server, config: Optional[FleetConfig] = None):
        from repro.service import server as server_mod   # no cycle: lazy
        self._server_mod = server_mod
        self.server = server
        self.cfg = config or FleetConfig()
        self.sup = IslandSupervisor(self.cfg)
        self._pending: List[dict] = []       # parked recovered rows
        self._down_until: Dict[int, int] = {}
        # service-level progress attribution: the summed-feval watermark the
        # engine supervisor uses is wrong for a multi-tenant island — lanes
        # share the island index (their pulls would fight over one
        # watermark) and a retired slot's re-use legitimately REGRESSES the
        # sum (new job restarts at 0).  So the controller keeps per-(lane,
        # island) row records keyed by job id, grades corrupt reads and
        # progress per same-job row, and feeds the health core ONE
        # aggregated observation per island per round.
        self._lane_rows: Dict[tuple, tuple] = {}  # (lane,isl)->(jobs,fevals)
        self._round: Dict[int, dict] = {}         # isl -> this round's obs
        self._live_next: Dict[int, int] = {}      # isl -> live rows dispatched
        self._expect: Dict[int, bool] = {}        # isl -> expect progress
        server.fleet = self
        if server.snapshot_dir and not server.snapshot_every:
            server.snapshot_every = self.cfg.snapshot_every
        if self.cfg.postmortem_dir:
            flight_recorder().out_dir = self.cfg.postmortem_dir

    @property
    def health(self) -> FleetHealth:
        """The fleet's detector — the server's boundary code reads island
        grades through ``server.fleet.health`` for its recorder feed."""
        return self.sup.health

    # hook points the server calls (see server._island_boundary)
    def pull(self, island: int, boundary: int, fn, lane=None, jobs=None):
        """Supervised boundary pull.  With ``lane``/``jobs`` (the service
        path) the monotonicity retry and the progress verdict are per
        same-job row: only a row still holding the job it held at the last
        pull can regress (corrupt read) or advance (progress) — slot re-use
        and multi-lane islands never alias.  Without them (engine paths)
        this defers to the island supervisor's summed-watermark pull."""
        if lane is None:
            return self.sup.pull(island, boundary, fn)
        t0 = time.perf_counter()
        k_idx, active, fevals, best_f = fn()
        if self.sup.plan is not None \
                and self.sup.plan.corrupts(island, boundary):
            fevals = np.zeros_like(fevals)      # garbled read, fired once
        jobs = np.asarray(jobs)
        prev = self._lane_rows.get((lane, island))
        tries = 0
        while prev is not None \
                and _rows_regressed(prev, jobs, fevals) \
                and tries < max(1, self.cfg.retries):
            tries += 1
            obs.metrics().counter("fleet_pull_retries_total",
                                  island=island).inc()
            if self.cfg.backoff_s:
                time.sleep(self.cfg.backoff_s * tries)
            k_idx, active, fevals, best_f = fn()
        rec = self._round.setdefault(island,
                                     {"wall": 0.0, "progressed": False})
        rec["wall"] = max(rec["wall"], time.perf_counter() - t0)
        rec["progressed"] = (rec["progressed"]
                             or _rows_advanced(prev, jobs, fevals))
        self._lane_rows[(lane, island)] = (jobs.copy(),
                                           np.asarray(fevals).copy())
        return k_idx, active, fevals, best_f

    def before_dispatch(self, island: int, boundary: int,
                        live_rows: Optional[int] = None):
        if live_rows is None:
            return self.sup.before_dispatch(island, boundary)
        if self.sup.plan is not None:
            d = self.sup.plan.delay(island, boundary)
            if d:
                time.sleep(d)
        # the island is only EXPECTED to progress next round if some live,
        # non-retired row was actually dispatched — an island whose only
        # residents are quarantined/finished rows dispatches nothing and
        # must never be graded "stalled"
        self._live_next[island] = (self._live_next.get(island, 0)
                                   + int(live_rows))

    def _grade_round(self, boundary: int):
        """Fold this round's per-lane pull records into one health
        observation per island, then roll the dispatch expectations."""
        for island, rec in self._round.items():
            self.sup.health.observe_progress(
                island, boundary, rec["progressed"], rec["wall"],
                expect_progress=self._expect.get(island, False))
        self._expect = {i: n > 0 for i, n in self._live_next.items()}
        self._round = {}
        self._live_next = {}

    # -- the supervised service loop ----------------------------------------

    def step(self):
        srv, b = self.server, self.server._boundary_n
        rejoined = [i for i, until in list(self._down_until.items())
                    if b >= until]
        for i in rejoined:
            self._rejoin(i, b)
        if self.cfg.plan is not None:
            for ev in self.cfg.plan.kills_at(b):
                if (ev.island < len(srv.devices)
                        and ev.island not in srv.down_islands):
                    self._fail_island(ev.island, b, "killed",
                                      down_for=ev.down_for)
        for i in self.sup.health.dead_islands():
            if i not in srv.down_islands and i < len(srv.devices):
                self._fail_island(
                    i, b, self.sup.health.island(i).reason or "deadline")
        self._place_pending()
        stats = srv.step()
        self._grade_round(b)
        if not srv.down_islands:
            self._maybe_rebalance("rejoin" if rejoined else "skew")
        return stats

    def drain(self, max_steps: int = 10_000):
        """Supervised ``server.drain``: also waits on parked recoveries
        (rows that could not be re-placed yet)."""
        import time as _t
        from repro.service.queue import JOB_REJECTED
        srv = self.server
        for _ in range(max_steps):
            stats = self.step()
            if (not stats.progressed() and not srv._resident_jobs()
                    and not self._pending and not len(srv.queue)):
                break
        else:
            raise RuntimeError(
                f"fleet did not drain in {max_steps} steps "
                f"({len(self._pending)} recoveries still parked)")
        while len(srv.queue):
            item = srv.queue.take()
            if item is None:
                break
            _req, t = item
            t.done_s = _t.monotonic()
            srv._transition(t, JOB_REJECTED, "unplaceable at idle")
            obs.metrics().counter("service_jobs_total",
                                  event="rejected").inc()
        return [t for t in srv.tickets.values() if t.done]

    # -- failure + recovery --------------------------------------------------

    def _fail_island(self, i: int, b: int, reason: str, down_for: int = 0):
        """Declare island ``i`` dead and recover every row it held: restore
        each resident job's state from the last on-disk snapshot (partial
        read of exactly that island's subtree) — or replay from its request
        if it was admitted after the snapshot — and re-place it on a
        surviving island (or park it)."""
        srv = self.server
        t0 = time.perf_counter()
        srv.down_islands.add(i)
        self.sup.health.mark_dead(i, b, reason)
        # drop the island's pull records + expectations: the recovered rows
        # re-land elsewhere and the rejoined island comes back blank
        self._lane_rows = {k: v for k, v in self._lane_rows.items()
                           if k[1] != i}
        self._round.pop(i, None)
        self._live_next.pop(i, None)
        self._expect.pop(i, None)
        reg = obs.metrics()
        reg.counter("fleet_failures_total", reason=reason).inc()
        frec = flight_recorder()
        # guarantee the fault boundary itself is the last timeline entry of
        # the post-mortem, whatever the island's pull cadence was
        frec.observe(i, b, event="fault", grade="dead", reason=reason)
        frec.dump(i, b, "dead",
                  extra={"reason": reason, "down_for": down_for})
        snap = self._open_snapshot()
        lost = 0.0
        with obs.tracer().span("recover", island=i, boundary=b,
                               reason=reason, mode="reassign") as rspan:
            moved = parked = 0
            for lane in srv.lanes.values():
                al = lane.allocator
                if i >= al.n_islands:
                    continue
                for row in np.nonzero(al.row_jobs[i] >= 0)[0]:
                    job = int(al.row_jobs[i][row])
                    al.release(i, int(row))
                    t = srv.tickets[job]
                    vals, tr_row, own_row, fev_snap = self._recover_job(
                        snap, lane, job, t)
                    lost += max(0.0, float(t.fevals) - fev_snap)
                    rec = {"lane_key": lane.key, "job": job, "vals": vals,
                           "trace": tr_row, "own": own_row,
                           "budget": int(t.request.budget),
                           "failed_island": i, "boundary": b}
                    if self._try_place(rec):
                        moved += 1
                    else:
                        parked += 1
                        self._pending.append(rec)
                        t.island = t.row = None
                        reg.counter("fleet_recoveries_total",
                                    mode="requeued").inc()
                        srv.note_recovery(job, i, "requeued", b)
            rspan.attrs["reassigned"] = moved
            rspan.attrs["requeued"] = parked
        if down_for:
            self._down_until[i] = b + down_for
        reg.histogram("fleet_recovery_wall_s").observe(
            time.perf_counter() - t0)
        reg.histogram("fleet_lost_work_evals").observe(lost)

    def _open_snapshot(self) -> Optional[dict]:
        srv = self.server
        if not srv.snapshot_dir:
            return None
        step = store.latest_step(srv.snapshot_dir)
        if step is None:
            return None
        meta = store.load_meta(srv.snapshot_dir, step)
        if meta is None:
            return None
        return {"step": step, "meta": meta, "cache": {}}

    def _recover_job(self, snap: Optional[dict], lane, job: int, t):
        """One job's recovered row: ``(vals, trace_row, own_row,
        fev_at_snapshot)``.  ``vals`` matches ``_Lane._write_row``'s
        structure; ``trace_row`` is the job's snapshot-era trace slice (or
        None when it replays from scratch)."""
        from repro.service.queue import JOB_RUNNING
        meta = snap["meta"] if snap is not None else None
        jm = meta["jobs"].get(str(job)) if meta is not None else None
        if jm is not None and jm["status"] == JOB_RUNNING \
                and jm.get("lane") == list(lane.key):
            li = next((n for n, lm in enumerate(meta["lanes"])
                       if tuple(lm["key"]) == lane.key), None)
            if li is not None:
                lmeta = meta["lanes"][li]
                oi, orow = int(jm["island"]), int(jm["row"])
                if lmeta["alloc"]["row_jobs"][oi][orow] == job:
                    entry = self._load_island(snap, lane, li, lmeta, oi)
                    vals = {
                        "keys": entry["keys"][orow],
                        "fn_idx": entry["fn_idx"][orow],
                        "budgets": entry["budgets"][orow],
                        "insts": jax.tree_util.tree_map(
                            lambda a: a[orow], entry["insts"]),
                        "carry": jax.tree_util.tree_map(
                            lambda a: a[orow], entry["carry"]),
                    }
                    tr_row = own_row = None
                    if "own" in entry:
                        mask = entry["own"][orow] == job
                        if mask.any():
                            tr_row = jax.tree_util.tree_map(
                                lambda a: a[orow][mask], entry["trace"])
                            own_row = entry["own"][orow][mask]
                    return vals, tr_row, own_row, float(jm["fevals"] or 0)
        # admitted after the snapshot (or no snapshot): replay from the
        # request — admission state is a pure function of it
        return self.server._job_vals(lane, t.request), None, None, 0.0

    def _load_island(self, snap: dict, lane, li: int, lmeta: dict,
                     oi: int) -> dict:
        """Partial snapshot read: exactly one (lane, island) subtree."""
        ck = (li, oi)
        if ck not in snap["cache"]:
            tmpl = self._server_mod._lane_template(lane, lmeta)
            template = {"lanes": {str(li): {"islands": {
                str(oi): tmpl["islands"][str(oi)]}}}}
            sub = store.restore(self.server.snapshot_dir, snap["step"],
                                template)
            snap["cache"][ck] = _host(
                sub)["lanes"][str(li)]["islands"][str(oi)]
        return snap["cache"][ck]

    def _try_place(self, rec: dict) -> bool:
        """Place one recovered row on the healthiest surviving island of
        its lane; False parks it for a later boundary."""
        srv = self.server
        lane = srv.lanes[rec["lane_key"]]
        al = lane.allocator
        cands = [j for j in range(al.n_islands)
                 if j not in srv.down_islands and al.free_rows(j) > 0]
        if not cands:
            return False
        j = max(cands, key=lambda x: (al.free_rows(x), -x))
        placed = al.alloc(rec["job"], rec["budget"], island=j)
        assert placed is not None
        _j, nr = placed
        isl = lane.islands[j]
        isl.arrays = lane._write_row(isl.arrays, rec["vals"], nr)
        if rec["own"] is not None:
            isl.traces.append(_expand_trace_row(
                al.rows_per_island, nr, rec["trace"], rec["job"]))
        t = srv.tickets[rec["job"]]
        t.lane, t.island, t.row = lane.key, j, nr
        obs.metrics().counter("fleet_recoveries_total",
                              mode="reassigned").inc()
        # stitch the job's trace across the failure: close the pre-failure
        # phase, mark the recovery, open a post-failure phase on the same root
        srv.note_recovery(rec["job"], rec.get("failed_island", -1),
                          "reassigned", rec.get("boundary", 0))
        return True

    def _place_pending(self):
        still = []
        for rec in self._pending:
            if not self._try_place(rec):
                still.append(rec)
        self._pending = still

    def _rejoin(self, i: int, b: int):
        """Re-admit a returned island: blank state (its rows were recovered
        elsewhere), alive again; the skew trigger repopulates it."""
        srv = self.server
        srv.down_islands.discard(i)
        self._down_until.pop(i, None)
        self.sup.health.revive(i, b)
        for lane in srv.lanes.values():
            if i < len(lane.islands):
                isl = lane.islands[i]
                isl.arrays = jax.device_put(lane._blank_arrays(), isl.device)
                isl.traces = []
        obs.metrics().counter("fleet_recoveries_total",
                              mode="rejoined").inc()

    # -- skew rebalancing ----------------------------------------------------

    def _maybe_rebalance(self, trigger: str):
        """Schedule a lane ``repack`` when slot occupancy is skewed beyond
        the threshold AND a repack can actually improve it (spread of
        occupied counts > 1 row).  Only with the whole fleet healthy —
        degraded mode defers rebalancing until islands return."""
        srv = self.server
        for lane in srv.lanes.values():
            al = lane.allocator
            if al.n_islands < 2 or not al.occupied():
                continue
            counts = occupancy_counts(al)
            if max(counts) - min(counts) <= 1:
                continue
            if occupancy_skew(al) <= self.cfg.skew_threshold:
                continue
            self._rebalance_lane(lane)
            obs.metrics().counter("fleet_rebalances_total",
                                  trigger=trigger).inc()

    def _rebalance_lane(self, lane):
        """Live repack: pull the lane's islands to host, lay the occupied
        rows back out round-robin across all islands (the allocator's
        repack order is island-major, hence balanced), and device_put each
        island back — the elastic re-shard path restore() uses, applied to
        a running lane."""
        srv = self.server
        ltree: dict = {"islands": {}}
        trace_T = {}
        for i, isl in enumerate(lane.islands):
            entry = _host(dict(isl.arrays))
            if isl.traces:
                entry["trace"] = jax.tree_util.tree_map(
                    lambda *xs: np.concatenate(
                        [np.asarray(x) for x in xs], axis=1),
                    *[t for t, _o in isl.traces])
                entry["own"] = np.concatenate(
                    [o for _t, o in isl.traces], axis=1)
                trace_T[str(i)] = int(entry["own"].shape[1])
            else:
                trace_T[str(i)] = 0
            ltree["islands"][str(i)] = entry
        lmeta = {"alloc": lane.allocator.to_meta(), "trace_T": trace_T}
        self._server_mod._repack_lane(srv, lane, lmeta, ltree)


def _expand_trace_row(Bl: int, row: int, tr_row, job: int):
    """Blow a recovered single-row trace slice back up to an island-shaped
    ``(trace, own)`` entry: row ``row`` carries the job's generations, every
    other row is inert (``own=-1`` → never sliced into any result)."""
    T = jax.tree_util.tree_leaves(tr_row)[0].shape[0]
    tr = jax.tree_util.tree_map(
        lambda a: np.zeros((Bl,) + a.shape, a.dtype), tr_row)
    for d, s in zip(jax.tree_util.tree_leaves(tr),
                    jax.tree_util.tree_leaves(tr_row)):
        d[row] = s
    own = np.full((Bl, T), -1, np.int64)
    own[row] = job
    return tr, own
