"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000
ssm_state=64 — Mamba2 backbone + shared full-attention block
[arXiv:2411.15242].

Simplification (DESIGN.md §5): a single shared transformer block (MHA + GLU
MLP over concat(x, x_embed₀), projected back to d_model) invoked after every
6th Mamba2 layer — 81 = 13 units of (6 mamba + shared-attn) + 3 tail mamba
layers.  The real Zamba2 alternates two shared blocks with per-invocation
LoRAs; the memory/compute shape is the same.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    d_ff=14336,
    vocab=32000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=9, d_model=64, d_ff=128, n_heads=4, n_kv_heads=4,
        head_dim=32, vocab=512, ssm_state=16, ssm_head_dim=16,
        shared_attn_every=3, q_chunk=32, logits_chunk=64)
