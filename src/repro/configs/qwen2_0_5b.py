"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA with QKV bias, tied embeddings [arXiv:2407.10671]."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    d_ff=4864,
    vocab=151936,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    qkv_bias=True,
    tied_embeddings=True,
    rope_theta=1e6,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=2, d_model=64, d_ff=128, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab=512, q_chunk=32, logits_chunk=64)
