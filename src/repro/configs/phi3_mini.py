"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32 ⇒ MHA) d_ff=8192
vocab=32064 — RoPE + SwiGLU [arXiv:2404.14219]."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab=32064,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=2, d_model=64, d_ff=192, n_heads=4, n_kv_heads=4,
        head_dim=16, vocab=512, q_chunk=32, logits_chunk=64)
