"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) d_ff=1408
(per expert) vocab=163840, MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B]."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    d_ff=1408,
    vocab=163840,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    n_experts=64,
    experts_per_tok=6,
    logits_chunk=1024,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=2, d_model=64, d_ff=96, n_heads=4, n_kv_heads=4,
        head_dim=16, vocab=512, n_experts=8, experts_per_tok=2,
        q_chunk=32, logits_chunk=64)
