"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local(sliding 1024):global interleave, dual RoPE theta
(10k local / 1M global), head_dim 128 decoupled from d_model, RMSNorm with
(1+w) scale [hf:google/gemma-3-*].

62 layers: 10 full (5 local + 1 global) pattern units + a 2-layer local tail
(the scanned stack handles the remainder — models/lm.py).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    d_ff=21504,
    vocab=262144,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    act="gelu",
    sliding_window=1024,
    local_per_global=5,
    rope_theta=1e4,
    rope_theta_global=1e6,
    logits_chunk=512,            # 262k vocab → small CE chunks
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=12, d_model=64, d_ff=128, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab=512, sliding_window=32, q_chunk=32,
        logits_chunk=64)
