"""Architecture registry: one module per assigned arch (+ the paper's own
CMA-ES campaign configs in ``cma_campaign.py``).

    from repro.configs import get_config, smoke_config, ARCHS
    cfg = get_config("qwen2-0.5b")
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (ModelConfig, ShapeSpec, SHAPES,
                                LONG_CONTEXT_ARCHS, cells_for)

ARCHS = (
    "musicgen-large",
    "qwen2-0.5b",
    "phi3-mini-3.8b",
    "gemma3-27b",
    "gemma3-4b",
    "rwkv6-3b",
    "moonshot-v1-16b-a3b",
    "phi3.5-moe-42b-a6.6b",
    "zamba2-7b",
    "llama-3.2-vision-90b",
)

_MODULES = {
    "musicgen-large": "musicgen_large",
    "qwen2-0.5b": "qwen2_0_5b",
    "phi3-mini-3.8b": "phi3_mini",
    "gemma3-27b": "gemma3_27b",
    "gemma3-4b": "gemma3_4b",
    "rwkv6-3b": "rwkv6_3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "zamba2-7b": "zamba2_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.ARCH


def smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke()


def override(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, **kw)
