"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536 —
RWKV-6 "Finch", data-dependent decay [arXiv:2404.05892]."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab=65536,
    rwkv_head_dim=64,
    norm="layernorm",
    pos="none",
    glu=False,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=2, d_model=64, d_ff=128, vocab=512, rwkv_head_dim=16,
        logits_chunk=64)
