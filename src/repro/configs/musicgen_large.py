"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32 ⇒ MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S, d_model); the backbone is a classic
pre-LN transformer (LayerNorm, GELU, no GLU, sinusoidal positions) with an
LM head over the 2048-entry codebook.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    d_ff=8192,
    vocab=2048,
    n_heads=32,
    n_kv_heads=32,
    norm="layernorm",
    act="gelu",
    glu=False,
    pos="sinusoidal",
    embed_inputs=False,          # frame embeddings come from the stub frontend
    logits_chunk=4096,           # tiny vocab → big chunks are fine
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=2, d_model=64, d_ff=256, n_heads=4, n_kv_heads=4,
        head_dim=16, vocab=256, q_chunk=32, logits_chunk=64)
