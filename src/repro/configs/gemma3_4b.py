"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144 — 5:1 local:global, 128k context [hf:google/gemma-3-4b-pt]."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    d_ff=10240,
    vocab=262144,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    act="gelu",
    sliding_window=1024,
    local_per_global=5,
    rope_theta=1e4,
    rope_theta_global=1e6,
    logits_chunk=512,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=12, d_model=64, d_ff=128, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab=512, sliding_window=32, q_chunk=32,
        logits_chunk=64)
