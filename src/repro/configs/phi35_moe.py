"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
(per expert) vocab=32064, MoE 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab=32064,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    n_experts=16,
    experts_per_tok=2,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=2, d_model=64, d_ff=96, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab=512, n_experts=4, experts_per_tok=2,
        q_chunk=32, logits_chunk=64)
