"""Architecture + shape configuration system.

Every assigned architecture is a ``ModelConfig`` (exact published dims) plus a
``smoke()`` reduction of the same family for CPU tests.  ``input_specs``
builds ShapeDtypeStruct stand-ins for every model input of a (arch × shape)
cell — the multi-pod dry-run lowers against these, never allocating.

Shapes (assignment):
    train_4k     seq 4096,    global_batch 256   → train_step
    prefill_32k  seq 32768,   global_batch 32    → prefill (serve)
    decode_32k   seq 32768,   global_batch 128   → serve_step (1 new token,
                                                   KV cache holding seq_len)
    long_500k    seq 524288,  global_batch 1     → serve_step, sub-quadratic
                                                   archs only (DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    n_heads: int = 0                # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    tied_embeddings: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"
    glu: bool = True                # gated FFN (SwiGLU/GeGLU)
    pos: str = "rope"               # rope | sinusoidal | none
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0  # gemma3 dual-theta (0 → same as local)
    # --- sliding/global interleave (gemma3) ----------------------------------
    sliding_window: int = 0         # 0 → all layers full attention
    local_per_global: int = 0       # e.g. 5 → pattern [5×local, 1×global]
    # --- MoE ------------------------------------------------------------------
    n_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25
    moe_dispatch: str = "global"    # global | rowwise (§Perf iteration 2)
    # --- SSM / RWKV -------------------------------------------------------------
    ssm_state: int = 0              # mamba2 d_state
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    rwkv_head_dim: int = 64
    # --- hybrid (zamba2) ---------------------------------------------------------
    shared_attn_every: int = 0      # mamba layers per shared-attn invocation
    # --- VLM / audio frontends (stubs) --------------------------------------------
    cross_every: int = 0            # 1 cross-attn layer per this many layers
    n_img_tokens: int = 0
    embed_inputs: bool = True       # False → inputs are precomputed embeddings
    # --- numerics / training ---------------------------------------------------
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    logits_chunk: int = 2048        # CE loss sequence-chunk (never full logits)
    q_chunk: int = 1024             # attention query chunk
    remat: bool = True
    # attention implementation on the XLA path:
    #   "naive" — paper-faithful-substrate baseline (materialized probs)
    #   "flash" — memory-linear custom-VJP flash (models/flash_xla.py);
    #             on TPU, kernels/flash_attention.py (Pallas) — §Perf iter 1
    attn_impl: str = "naive"
    # re-shard the attention batch over ("data","model") at layer boundaries
    # so archs whose head count does not divide the model axis (qwen2: 14
    # heads on 16-way TP) still shard attention compute — §Perf iter 1.4
    attn_batch_tp: bool = False
    # -------------------------------------------------------------------------

    def __post_init__(self):
        if self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def layer_pattern(self) -> Tuple[int, int]:
        """(unit_len, n_units[, tail]) decomposition used by the scanned stack."""
        if self.family == "vlm" and self.cross_every:
            unit = self.cross_every
            assert self.n_layers % unit == 0
            return unit, self.n_layers // unit
        if self.local_per_global:
            unit = self.local_per_global + 1
            assert self.n_layers % unit == 0 or self.n_layers % unit != 0
            return unit, self.n_layers // unit
        if self.family == "hybrid" and self.shared_attn_every:
            unit = self.shared_attn_every
            return unit, self.n_layers // unit
        return 1, self.n_layers

    def n_params(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        n = 0
        if self.embed_inputs:
            n += V * d
        if not self.tied_embeddings:
            n += V * d
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            H, Hk, Dh = self.n_heads, self.n_kv_heads, self.head_dim
            attn = d * H * Dh + 2 * d * Hk * Dh + H * Dh * d
            if self.family == "moe":
                ffp = self.n_experts * (d * ff * (3 if self.glu else 2)) + d * self.n_experts
            else:
                ffp = d * ff * (3 if self.glu else 2)
            per_layer = attn + ffp + 2 * d
        if self.family == "ssm":                      # rwkv6
            per_layer = 6 * d * d + d * ff * 2 + d * d  # tmix + cmix approx
        if self.family == "hybrid":                   # zamba2: mamba layers
            d_in = self.ssm_expand * d
            per_layer = d * (2 * d_in + 2 * self.ssm_state +
                             d_in // self.ssm_head_dim) + d_in * d
            # one shared attention+mlp block
            H, Dh = self.n_heads, self.head_dim
            n += 2 * d * H * Dh + 2 * d * H * Dh + d * ff * (3 if self.glu else 2)
        n += per_layer * self.n_layers
        return n

    def n_active_params(self) -> int:
        """MoE: params touched per token (MODEL_FLOPS uses this)."""
        if self.family != "moe":
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense = self.n_params() - self.n_layers * self.n_experts * (
            d * ff * (3 if self.glu else 2))
        active_ff = self.n_layers * self.experts_per_tok * (
            d * ff * (3 if self.glu else 2))
        return dense + active_ff


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs whose attention is sub-quadratic at decode (long_500k applicability —
# DESIGN.md §5): attention-free, hybrid-with-O(1)-state, or sliding-window
# dominated.  Pure full-attention archs skip the cell.
LONG_CONTEXT_ARCHS = ("rwkv6-3b", "zamba2-7b", "gemma3-27b", "gemma3-4b")


def cells_for(arch_name: str):
    """The (shape) list assigned to an architecture."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_name in LONG_CONTEXT_ARCHS:
        shapes.append("long_500k")
    return shapes
