"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attention image layers [hf:meta-llama/Llama-3.2-*-Vision].

100 layers = 20 pattern units of (4 self-attn + 1 gated cross-attn).  The
vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed image patch embeddings (B, n_img_tokens, d_model); cross-attn KV
is computed once and cached for decode.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    d_ff=28672,
    vocab=128256,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    rope_theta=5e5,
    cross_every=5,
    n_img_tokens=1601,           # (448/14)² + 1 CLS, one tile
    logits_chunk=1024,
)


def smoke() -> ModelConfig:
    return dataclasses.replace(
        ARCH, n_layers=10, d_model=64, d_ff=128, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab=512, cross_every=5, n_img_tokens=17,
        q_chunk=32, logits_chunk=64)
