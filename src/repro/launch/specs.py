"""ShapeDtypeStruct stand-ins for every (arch × shape) dry-run cell.

``input_specs(cfg, shape)`` returns the abstract inputs of the step function
that cell lowers — weak-type-correct, shardable, zero allocation:

  train_4k     → {"tokens"/"frames", "labels"[, "img_embeds"]}
  prefill_32k  → {"tokens"/"frames"[, "img_embeds"]}
  decode_32k / long_500k → ({"tokens"/"frames"}, cache-abstract)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import lm


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, B: int, S: int, *, labels: bool) -> dict:
    out = {}
    if cfg.embed_inputs:
        out["tokens"] = _sds((B, S), jnp.int32)
    else:                                        # audio stub frontend
        out["frames"] = _sds((B, S, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        out["img_embeds"] = _sds((B, cfg.n_img_tokens, cfg.d_model), cfg.dtype)
    if labels:
        out["labels"] = _sds((B, S), jnp.int32)
    return out


def cache_abstract(cfg: ModelConfig, B: int, max_len: int):
    return jax.eval_shape(lambda: lm.init_cache(cfg, B, max_len))


def params_abstract(cfg: ModelConfig, dtype=None):
    abstract = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                              jax.ShapeDtypeStruct((2,), jnp.uint32))
    if dtype is not None:
        abstract = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.dtype(dtype)), abstract)
    return abstract


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract *data* inputs of the cell's step function."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return batch_specs(cfg, B, S, labels=True)
    if shape.kind == "prefill":
        return batch_specs(cfg, B, S, labels=False)
    if shape.kind == "decode":
        step_in = batch_specs(cfg, B, 1, labels=False)
        # decode over a VLM: cross-KV lives in the cache; img_embeds not fed
        step_in.pop("img_embeds", None)
        return step_in
    raise ValueError(shape.kind)
