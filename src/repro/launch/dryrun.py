import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede every other import (jax locks the device count on first init).

r"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating a single model buffer:
  * proof the sharding config is coherent (``.lower().compile()`` succeeds),
  * ``compiled.memory_analysis()``  — bytes/device (fits-in-HBM evidence),
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the optimized HLO (per collective kind),
all dumped as one JSON artifact per cell under ``benchmarks/artifacts/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--cma]

Exit code 0 iff every requested cell compiled.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES, cells_for
from repro.distributed import sharding
from repro.distributed.hlo_analyzer import analyze
from repro.launch import mesh as mesh_mod
from repro.launch import specs as specs_mod
from repro.serve import engine as serve_engine
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../benchmarks/artifacts")

# grad-accumulation depth per arch for train_4k (fits-in-HBM tuning; the
# dry-run memory analysis below is the evidence)
MICROBATCHES = {
    "gemma3-27b": 8,
    "llama-3.2-vision-90b": 16,
    "phi3.5-moe-42b-a6.6b": 8,
    "zamba2-7b": 4,
    "moonshot-v1-16b-a3b": 4,
    "phi3-mini-3.8b": 2,
    "gemma3-4b": 2,
    "rwkv6-3b": 2,
    "musicgen-large": 2,
}


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))


def _batch_shardings(mesh, batch_abstract):
    dp = sharding.dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_size = 1
    for a in dp:
        dp_size *= sizes[a]

    def spec(x):
        lead = dp if (x.shape and x.shape[0] % dp_size == 0) else None
        return jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(lead,
                                             *([None] * (len(x.shape) - 1))))
    return jax.tree_util.tree_map(spec, batch_abstract)


def lower_cell(arch: str, shape_name: str, mesh, *, microbatches=None,
               overrides: dict | None = None):
    """Returns (lowered, compiled, meta) for one cell.

    ``overrides`` — ModelConfig field overrides for §Perf experiments
    (e.g. {"attn_impl": "flash"}); recorded in the artifact.
    """
    import dataclasses
    cfg = get_config(arch)
    overrides = dict(overrides or {})
    # TrainConfig-level knobs routed out of the ModelConfig overrides
    tknobs = {k: overrides.pop(k) for k in
              ("grad_accum_dtype", "shard_grad_accum", "grad_compress")
              if k in overrides}
    if "shard_grad_accum" in tknobs:
        tknobs["shard_grad_accum"] = bool(int(tknobs["shard_grad_accum"]))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    sharding.set_mesh(mesh)

    if shape.kind == "train":
        mb = microbatches if microbatches is not None else \
            MICROBATCHES.get(arch, 1)
        tcfg = ts_mod.TrainConfig(microbatches=mb, **tknobs)
        params_abs = specs_mod.params_abstract(cfg)
        opt_abs = jax.eval_shape(opt_mod.init_opt_state, params_abs)
        batch_abs = specs_mod.input_specs(cfg, shape)
        psh, opt_sh, _ = ts_mod.shardings_for(cfg, mesh,
                                              params_abstract=params_abs)
        bsh = _batch_shardings(mesh, batch_abs)
        step = ts_mod.make_train_step(cfg, tcfg, mesh)
        lowered = jax.jit(step, in_shardings=(psh, opt_sh, bsh)).lower(
            params_abs, opt_abs, batch_abs)
    elif shape.kind == "prefill":
        params_abs = specs_mod.params_abstract(cfg, dtype=cfg.dtype)
        batch_abs = specs_mod.input_specs(cfg, shape)
        psh = _named(mesh, sharding.param_specs(params_abs, mesh))
        bsh = _batch_shardings(mesh, batch_abs)
        fn = serve_engine.make_prefill(cfg, shape.seq_len, mesh)
        lowered = jax.jit(fn, in_shardings=(psh, bsh)).lower(
            params_abs, batch_abs)
    else:                                               # decode
        B = shape.global_batch
        params_abs = specs_mod.params_abstract(cfg, dtype=cfg.dtype)
        cache_abs = specs_mod.cache_abstract(cfg, B, shape.seq_len)
        batch_abs = specs_mod.input_specs(cfg, shape)
        psh = _named(mesh, sharding.param_specs(params_abs, mesh))
        csh = _named(mesh, sharding.cache_specs(cache_abs, mesh, B))
        bsh = _batch_shardings(mesh, batch_abs)
        fn = serve_engine.make_serve_step(cfg, mesh)
        lowered = jax.jit(fn, in_shardings=(psh, csh, bsh)).lower(
            params_abs, cache_abs, batch_abs)

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):                # jax 0.4.x returns [dict]
        cost = cost[0] if cost else {}
    stats = analyze(compiled.as_text())   # loop-trip-corrected (per device)
    n_dev = mesh.devices.size
    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_devices": n_dev,
        "kind": shape.kind,
        "compile_seconds": round(compile_s, 1),
        "flops": stats["flops"],
        "bytes_accessed": stats["bytes"],
        "collective_bytes": stats["collective_bytes"],
        "tagged_bytes": stats.get("tagged_bytes", {}),
        "unknown_trip_whiles": stats["unknown_trip_whiles"],
        # raw XLA numbers (while bodies counted once — see hlo_analyzer.py)
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes",
                                      getattr(mem, "temp_size_in_bytes", 0))),
        },
        "model": {
            "n_params": get_config(arch).n_params(),
            "n_active_params": get_config(arch).n_active_params(),
        },
        "overrides": dict(overrides, **tknobs),
    }
    return lowered, compiled, meta


def run_cma_dryrun(mesh, multi_pod: bool):
    """Lower the CMA-ES K-Distributed strategy step on the production mesh —
    the paper's technique as a first-class dry-run cell."""
    from repro.core.strategies import KDistributed
    from repro.fitness import bbob

    n_dev = mesh.devices.size
    inst = bbob.make_instance(8, 40, 1)
    fit = lambda X: bbob.evaluate(8, inst, X)
    kd = KDistributed(n=40, n_devices=n_dev, lam_start=12, dtype="float64")
    lowered = kd.lower_step(mesh, fit, chunk=1)
    t0 = time.time()
    compiled = lowered.compile()
    stats = analyze(compiled.as_text())
    return {
        "arch": "cma-kdistributed-f8-d40", "shape": "gen_step",
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_devices": n_dev, "kind": "cma",
        "compile_seconds": round(time.time() - t0, 1),
        "flops": stats["flops"],
        "bytes_accessed": stats["bytes"],
        "collective_bytes": stats["collective_bytes"],
        "memory": {}, "model": {},
    }


def run_gen_kernel_dryrun(mesh, multi_pod: bool):
    """Lower the slot-batched fused generation megakernels
    (kernels/cma_gen.py — sample + update, one slot per ladder rung) at the
    paper's n = 40 geometry as a first-class dry-run cell.  On TPU
    toolchains this exercises the Mosaic lowering; elsewhere the interpret
    lowering still yields the roofline flops/bytes of the fused path."""
    import jax.numpy as jnp

    from repro.core import cmaes, ladder

    eng = ladder.LadderEngine(n=40, lam_start=12, kmax_exp=4,
                              schedule="concurrent", impl="pallas",
                              dtype="float64")
    carry = eng.init_carry(jax.random.PRNGKey(0))
    S, lam_max, n = eng.n_slots, eng.lam_max, eng.n
    Z_abs = jax.ShapeDtypeStruct((S, lam_max, n), eng.cfg.jdtype)

    def mega(states, Z):
        Y, X = cmaes.kops.gen_sample(states.m, states.sigma, states.B,
                                     states.D, Z, impl="pallas")
        W = jnp.ones((S, lam_max), eng.cfg.jdtype) / lam_max
        from repro.core.params import select_params
        params_k = select_params(eng.sparams, jnp.arange(S))
        coef = cmaes.gen_coef(params_k, states)
        return cmaes.kops.gen_update(states.C, states.B, states.D,
                                     states.p_sigma, states.p_c, Y, W, coef,
                                     impl="pallas")

    lowered = jax.jit(mega).lower(
        jax.eval_shape(lambda c: c.states, carry), Z_abs)
    t0 = time.time()
    compiled = lowered.compile()
    stats = analyze(compiled.as_text())
    return {
        "arch": "cma-genmegakernel-d40", "shape": "slots_gen_step",
        "mesh": "1", "n_devices": 1, "kind": "cma",
        "compile_seconds": round(time.time() - t0, 1),
        "flops": stats["flops"],
        "bytes_accessed": stats["bytes"],
        "collective_bytes": stats["collective_bytes"],
        "memory": {}, "model": {},
        "engine": {"slots": S, "lam_max": lam_max, "n": n,
                   "impl": "pallas"},
    }


def run_mesh_engine_dryrun(mesh, multi_pod: bool):
    """Lower one shard_map segment of the mesh campaign engine (S1 ordered,
    widest rung bucket, one member per device) with the production mesh's
    devices re-viewed as a flat ("camp",) campaign axis — the paper's actual
    deployment (distributed/mesh_engine.py) as a first-class dry-run cell.
    The psum/pmin carry reduction shows up in ``collective_bytes``."""
    from repro.distributed import mesh_engine
    from repro.launch.mesh import make_campaign_mesh

    camp = make_campaign_mesh(devices=mesh.devices.flat)
    eng = mesh_engine.MeshCampaignEngine(
        n=40, lam_start=12, kmax_exp=4, max_evals=200_000,
        eigen_interval=5, mesh=camp)
    lowered, geo = mesh_engine.lower_ordered_segment(eng, fid=8, seg_blocks=1)
    t0 = time.time()
    compiled = lowered.compile()
    stats = analyze(compiled.as_text())
    return {
        "arch": "cma-meshcampaign-f8-d40", "shape": "segment",
        "mesh": "x".join(map(str, camp.devices.shape)),
        "n_devices": int(camp.devices.size), "kind": "cma",
        "compile_seconds": round(time.time() - t0, 1),
        "flops": stats["flops"],
        "bytes_accessed": stats["bytes"],
        "collective_bytes": stats["collective_bytes"],
        "memory": {}, "model": {}, "engine": geo,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--cma", action="store_true",
                    help="also dry-run the CMA-ES strategy step")
    ap.add_argument("--out-dir", default=ARTIFACT_DIR)
    ap.add_argument("--suffix", default="",
                    help="artifact-name suffix for §Perf variants")
    ap.add_argument("--set", action="append", default=[],
                    metavar="KEY=VAL", help="ModelConfig override, e.g. "
                    "--set attn_impl=flash --set microbatches=2")
    args = ap.parse_args(argv)

    overrides: dict = {}
    microbatches = None
    for kv in args.set:
        k, v = kv.split("=", 1)
        if k == "microbatches":
            microbatches = int(v)
            continue
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    os.makedirs(args.out_dir, exist_ok=True)
    mesh = mesh_mod.make_production_mesh(multi_pod=args.multi_pod)
    tag = "multipod" if args.multi_pod else "pod"

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in cells_for(arch):
                cells.append((arch, shape))
    elif args.arch and args.shape:
        cells.append((args.arch, args.shape))
    elif not args.cma:
        ap.error("--arch/--shape, --all, or --cma required")

    failures = []
    for arch, shape in cells:
        name = f"{arch}__{shape}__{tag}{args.suffix}"
        try:
            _, _, meta = lower_cell(arch, shape, mesh,
                                    microbatches=microbatches,
                                    overrides=overrides or None)
            with open(os.path.join(args.out_dir, name + ".json"), "w") as f:
                json.dump(meta, f, indent=1)
            print(f"OK   {name}  flops={meta['flops']:.3e} "
                  f"coll={meta['collective_bytes']['total']:.3e}B "
                  f"compile={meta['compile_seconds']}s", flush=True)
        except Exception as e:
            failures.append((name, e))
            print(f"FAIL {name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()

    n_extra = 0
    if args.cma:
        for name, runner in ((f"cma__kdist__{tag}", run_cma_dryrun),
                             (f"cma__meshcampaign__{tag}",
                              run_mesh_engine_dryrun),
                             (f"cma__genkernel__{tag}",
                              run_gen_kernel_dryrun)):
            n_extra += 1
            try:
                meta = runner(mesh, args.multi_pod)
                with open(os.path.join(args.out_dir, name + ".json"),
                          "w") as f:
                    json.dump(meta, f, indent=1)
                print(f"OK   {name}  flops={meta['flops']:.3e} "
                      f"coll={meta['collective_bytes']['total']:.3e}B",
                      flush=True)
            except Exception as e:
                failures.append((name, e))
                print(f"FAIL {name}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()

    print(f"\n{len(cells) + n_extra - len(failures)} ok, "
          f"{len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
