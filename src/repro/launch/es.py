"""CMA-ES campaign launcher — the paper's experiment driver.

  PYTHONPATH=src python -m repro.launch.es --strategy kdist --fid 8 \
      --dim 10 --devices 8 --gens 200 [--cost-ms 1]

Strategies: seq (paper Alg. 2 baseline) | kdist | krep.  On this container
the strategies run via the vmap simulation path (bit-identical program to
the shard_map deployment — see core/strategies.py).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

jax.config.update("jax_enable_x64", True)   # CMA-ES follows the f64 C code

import numpy as np

from repro.core.ipop import run_ipop
from repro.core.strategies import KDistributed, KReplicated
from repro.fitness import bbob


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", choices=("seq", "kdist", "krep"),
                    default="kdist")
    ap.add_argument("--fid", type=int, default=8)
    ap.add_argument("--dim", type=int, default=10)
    ap.add_argument("--instance", type=int, default=1)
    ap.add_argument("--devices", type=int, default=8,
                    help="simulated device count (vmap width)")
    ap.add_argument("--gens", type=int, default=200)
    ap.add_argument("--max-evals", type=int, default=100_000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    inst = bbob.make_instance(args.fid, args.dim, args.instance)
    fit = lambda X: bbob.evaluate(args.fid, inst, X)
    key = jax.random.PRNGKey(args.seed)
    t0 = time.time()

    if args.strategy == "seq":
        res = run_ipop(fit, args.dim, key, max_evals=args.max_evals)
        best, fevals = res.best_f, res.total_fevals
    elif args.strategy == "kdist":
        kd = KDistributed(n=args.dim, n_devices=args.devices)
        carry, trace = kd.run_sim(key, fit, total_gens=args.gens)
        best, fevals = float(carry.best_f), int(np.sum(carry.fevals))
    else:
        kr = KReplicated(n=args.dim, n_devices=args.devices)
        out = kr.run_sim(key, fit, phase_gens=args.gens,
                         max_evals=args.max_evals)
        best, fevals = out["best_f"], out["fevals"]

    err = best - float(inst.f_opt)
    summary = dict(strategy=args.strategy, fid=args.fid, dim=args.dim,
                   best_error=err, fevals=fevals,
                   wall_s=round(time.time() - t0, 2))
    if args.json:
        print(json.dumps(summary))
    else:
        print(f"[es] {args.strategy} f{args.fid} d{args.dim}: "
              f"error={err:.3e} after {fevals} evals "
              f"({summary['wall_s']}s)")


if __name__ == "__main__":
    main()
