"""Campaign-service CLI: serve a stream of optimization requests.

  PYTHONPATH=src python -m repro.launch.serve_campaigns \
      [--requests reqs.json | --synthetic 8] [--devices 4] \
      [--snapshot-dir ckpt --snapshot-every 4] [--resume] [--out results.json] \
      [--fleet] [--chaos-kills "0:3:2"] [--metrics-out metrics.jsonl] \
      [--metrics-port 9100] [--trace-out trace.json] [--postmortem-dir pm]

``--metrics-out`` appends one JSONL record of every live ``repro.obs``
series per service round (docs/METRICS.md documents the series and how to
read a run); ``--metrics-port`` additionally serves the prometheus-style
text exposition at ``GET /metrics`` for dashboards to scrape, plus a JSON
``GET /statusz`` snapshot (lanes, per-island occupancy + health grade,
queue depth, registry generation, active trace count).

``--trace-out PATH`` exports the run's span trace on exit: PATH gets the
Chrome ``trace_event`` JSON (open it in ui.perfetto.dev — one lane track
per island, one async track per job) and ``PATH + 'l'`` (``.jsonl``) gets
the raw span records that ``python -m repro.obs.trace --summarize``
digests.  ``--postmortem-dir`` arms the flight recorder: an island graded
DEAD or a job quarantine dumps ``postmortem-<island>-<boundary>.json``
there with the island's last-K boundary observations and spans.

``--fleet`` wraps the service in a ``repro.fleet.FleetController``:
boundary pulls are health-graded (deadline/stall detection), dead islands
are recovered from the last snapshot onto survivors, returning islands are
re-admitted, and lanes repack when slot-occupancy skew exceeds
``--fleet-skew``.  Supervision wants a ``--snapshot-dir`` (recovery
restores from it; without one, rows replay from their requests).
``--chaos-kills "island:boundary[:down_for],..."`` injects a deterministic
kill schedule through the same controller — the operational fire drill.

``--requests`` takes a JSON list of CampaignRequest dicts, each optionally
carrying an ``arrival_s`` wall-clock offset; ``--synthetic N`` generates a
mixed-dim BBOB trace instead.  Requests are fed to the server as their
arrival time passes while the service loop runs — admission happens at the
next segment boundary, exactly the streaming deployment the service exists
for.  With ``--devices > 1`` the process re-execs itself under
``--xla_force_host_platform_device_count`` (the bench_mesh pattern: the flag
must precede jax's first import) and every lane runs one island per virtual
device.  ``--resume`` restores the newest committed snapshot from
``--snapshot-dir`` instead of starting fresh (custom fitness callables
cannot ride a snapshot — the CLI serves BBOB requests only).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_INNER_ENV = "_SERVE_CAMPAIGNS_INNER"


def _parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", default=None,
                    help="JSON file with a list of request dicts")
    ap.add_argument("--synthetic", type=int, default=0,
                    help="generate N synthetic BBOB requests instead")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--dims", default="4,8",
                    help="dim menu for --synthetic")
    ap.add_argument("--fids", default="1,8",
                    help="compiled-in BBOB menu (and --synthetic draw set)")
    ap.add_argument("--budget", type=int, default=4000)
    ap.add_argument("--lam-start", type=int, default=8)
    ap.add_argument("--kmax", type=int, default=2)
    ap.add_argument("--rows-per-island", type=int, default=4)
    ap.add_argument("--arrival-gap-s", type=float, default=0.0,
                    help="synthetic inter-arrival gap (0 = all at t=0)")
    ap.add_argument("--queue-ttl-s", type=float, default=None,
                    help="per-request queue TTL stamped on synthetic "
                         "requests (expired while queued -> status=expired)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request run deadline stamped on synthetic "
                         "requests (enforced at segment boundaries)")
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="snapshot cadence in service rounds")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--max-steps", type=int, default=10_000)
    ap.add_argument("--fleet", action="store_true",
                    help="supervise the service with a FleetController "
                         "(health monitoring + snapshot recovery)")
    ap.add_argument("--fleet-deadline-s", type=float, default=30.0,
                    help="boundary-pull deadline before an island is "
                         "suspect")
    ap.add_argument("--fleet-skew", type=float, default=0.5,
                    help="slot-occupancy skew that triggers a lane repack")
    ap.add_argument("--chaos-kills", default=None,
                    help="injected kill schedule "
                         "'island:boundary[:down_for],...' (implies "
                         "--fleet)")
    ap.add_argument("--out", default=None, help="write results JSON here")
    ap.add_argument("--metrics-out", default=None,
                    help="append a metrics JSONL record every service round")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics on 127.0.0.1:PORT (0=ephemeral)")
    ap.add_argument("--trace-out", default=None,
                    help="write a Perfetto-loadable trace_event JSON here "
                         "on exit (raw spans land beside it as .jsonl)")
    ap.add_argument("--postmortem-dir", default=None,
                    help="flight-recorder dump directory (island death or "
                         "job quarantine writes postmortem-*.json here)")
    return ap


def main(argv=None):
    args = _parser().parse_args(argv)
    if args.devices > 1 and os.environ.get(_INNER_ENV) != "1":
        env = dict(os.environ)
        env[_INNER_ENV] = "1"
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + env.get("XLA_FLAGS", ""))
        cmd = [sys.executable, "-m", "repro.launch.serve_campaigns"]
        cmd += list(argv) if argv is not None else sys.argv[1:]
        return subprocess.run(cmd, check=True, env=env).returncode
    return _serve(args)


def _synthetic_requests(args):
    import numpy as np
    rng = np.random.default_rng(args.seed)
    dims = [int(d) for d in args.dims.split(",")]
    fids = [int(f) for f in args.fids.split(",")]
    reqs = []
    for j in range(args.synthetic):
        spec = {
            "dim": int(rng.choice(dims)),
            "fid": int(rng.choice(fids)),
            "instance": 1,
            "budget": int(args.budget * rng.uniform(0.5, 1.5)),
            "seed": int(rng.integers(0, 2 ** 31)),
            "priority": int(rng.integers(0, 3)),
            "arrival_s": round(j * args.arrival_gap_s, 4),
            "tag": f"synthetic-{j}",
            # stable dedup key: resubmits after shed/backpressure are
            # idempotent — a live or completed ticket is returned as-is
            "dedup_key": f"syn-{args.seed}-{j}",
        }
        if args.queue_ttl_s is not None:
            spec["queue_ttl_s"] = args.queue_ttl_s
        if args.deadline_s is not None:
            spec["deadline_s"] = args.deadline_s
        reqs.append(spec)
    return reqs


def _serve(args):
    import time

    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.service import CampaignRequest, CampaignServer, QueueFull

    if args.requests:
        with open(args.requests) as fh:
            raw = json.load(fh)
    elif args.synthetic:
        raw = _synthetic_requests(args)
    elif args.resume:
        raw = []                        # serve only the snapshot's jobs
    else:
        raise SystemExit("pass --requests FILE or --synthetic N")
    raw = sorted(raw, key=lambda r: r.get("arrival_s", 0.0))

    fids = tuple(int(f) for f in args.fids.split(","))
    if args.resume:
        if not args.snapshot_dir:
            raise SystemExit("--resume requires --snapshot-dir")
        srv = CampaignServer.restore(args.snapshot_dir,
                                     snapshot_every=args.snapshot_every)
        srv.metrics_out = args.metrics_out      # serving-process property
        print(f"[serve] resumed: {srv.stats()}", flush=True)
        raw = []                    # resumed queue/jobs come from the snapshot
    else:
        srv = CampaignServer(bbob_fids=fids, lam_start=args.lam_start,
                             kmax_exp=args.kmax,
                             max_budget=max((r["budget"] for r in raw),
                                            default=args.budget),
                             rows_per_island=args.rows_per_island,
                             devices=jax.devices(),
                             snapshot_dir=args.snapshot_dir,
                             snapshot_every=args.snapshot_every,
                             metrics_out=args.metrics_out)
    from repro import obs
    from repro.obs.recorder import recorder as flight_recorder
    if args.postmortem_dir:
        flight_recorder().out_dir = args.postmortem_dir
    if args.metrics_port is not None:
        _httpd, port = obs.start_metrics_server(port=args.metrics_port,
                                                status_fn=srv.statusz)
        print(f"[serve] metrics at http://127.0.0.1:{port}/metrics, "
              f"status at /statusz", flush=True)

    ctl = None
    if args.fleet or args.chaos_kills:
        from repro.fleet import FaultPlan, FleetConfig
        from repro.fleet.controller import FleetController
        plan = FaultPlan.parse(args.chaos_kills) if args.chaos_kills else None
        ctl = FleetController(srv, FleetConfig(
            snapshot_every=args.snapshot_every or 4, plan=plan,
            deadline_s=args.fleet_deadline_s,
            skew_threshold=args.fleet_skew,
            postmortem_dir=args.postmortem_dir))
        print(f"[serve] fleet supervision on "
              f"(snapshot_every={srv.snapshot_every or ctl.cfg.snapshot_every}"
              f"{', chaos plan ' + args.chaos_kills if plan else ''})",
              flush=True)

    t0 = time.monotonic()
    tickets = []
    specs_by_job = {}
    resubmitted = set()
    for step_i in range(args.max_steps):
        now = time.monotonic() - t0
        while raw and raw[0].get("arrival_s", 0.0) <= now:
            spec = dict(raw.pop(0))
            spec.pop("arrival_s", None)
            try:
                t = srv.submit(CampaignRequest(**spec))
                tickets.append(t)
                specs_by_job[t.job_id] = spec
                print(f"[serve] +job {t.job_id} dim={t.request.dim} "
                      f"fid={t.request.fid} budget={t.request.budget} "
                      f"prio={t.request.priority}", flush=True)
            except QueueFull:
                raw.insert(0, spec)             # backpressure: retry later
                break
        stats = ctl.step() if ctl is not None else srv.step()
        for t in srv.tickets.values():
            if t.done and not getattr(t, "_printed", False):
                t._printed = True
                lat = t.latency_s()
                lat_s = f"{lat:.3f}s" if lat is not None else "n/a (resumed)"
                print(f"[serve] -job {t.job_id} done best_f={t.best_f:.6g} "
                      f"fevals={t.fevals} latency={lat_s}", flush=True)
            elif t.terminal and not getattr(t, "_printed", False):
                t._printed = True
                print(f"[serve] -job {t.job_id} {t.status}"
                      f"{': ' + t.reason if t.reason else ''}", flush=True)
            # resubmit contract: a shed ticket is re-queued once with its
            # original spec — the dedup key makes the retry idempotent
            if (t.status == "shed" and t.job_id in specs_by_job
                    and t.job_id not in resubmitted):
                resubmitted.add(t.job_id)
                retry = dict(specs_by_job[t.job_id])
                retry["arrival_s"] = now
                raw.insert(0, retry)
                print(f"[serve] ~job {t.job_id} shed, resubmitting "
                      f"(dedup_key={retry.get('dedup_key')})", flush=True)
        if (not stats.progressed() and not raw and not len(srv.queue)
                and not srv._resident_jobs()
                and not (ctl is not None and ctl._pending)):
            break
    wall = time.monotonic() - t0

    done = [t for t in srv.tickets.values() if t.done]
    statuses = {}
    for t in srv.tickets.values():
        statuses[t.status] = statuses.get(t.status, 0) + 1
    summary = {
        "wall_s": round(wall, 3),
        "jobs": len(srv.tickets),
        "done": len(done),
        "statuses": statuses,
        "useful_evals": int(sum(t.fevals for t in done)),
        "stats": srv.stats(),
        "results": [{"job_id": t.job_id, "tag": t.request.tag,
                     "dim": t.request.dim, "fid": t.request.fid,
                     "best_f": t.best_f, "fevals": t.fevals,
                     "latency_s": t.latency_s()} for t in sorted(
                         done, key=lambda t: t.job_id)],
    }
    print(json.dumps({k: v for k, v in summary.items() if k != "results"},
                     indent=2))
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(summary, fh, indent=2)
        print(f"[serve] wrote {args.out}")
    if args.trace_out:
        n = obs.tracer().export_chrome(args.trace_out)
        nj = obs.tracer().export_jsonl(args.trace_out + "l")
        print(f"[serve] wrote {args.trace_out} ({n} trace events; "
              f"{nj} spans in {args.trace_out}l) — open in ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    sys.exit(main())
