"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --steps 200 --seq-len 128 --global-batch 8 [--smoke] \
      [--ckpt-dir /tmp/ckpt] [--microbatches 2] [--grad-compress int8]

On this CPU container use ``--smoke`` (reduced config of the same family).
On a real pod the same entrypoint runs the full config across the production
mesh (mesh axes picked from the device count).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_config, smoke_config
from repro.launch import mesh as mesh_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compress", choices=("none", "int8"),
                    default="none")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if len(jax.devices()) > 1:
        mesh = mesh_mod.make_mesh_for(model_parallel=args.model_parallel)

    tc = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        train=ts_mod.TrainConfig(
            microbatches=args.microbatches,
            grad_compress=args.grad_compress,
            adamw=opt_mod.AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                                      total_steps=args.steps)))
    trainer = Trainer(cfg, tc, seq_len=args.seq_len,
                      global_batch=args.global_batch, mesh=mesh)
    trainer.run(resume=not args.no_resume)
    final = trainer.history[-1]["loss"] if trainer.history else float("nan")
    print(f"[train] done: {args.steps} steps, final loss {final:.4f}")


if __name__ == "__main__":
    main()
