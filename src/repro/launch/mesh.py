"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* the first
jax device query, and smoke tests must keep seeing 1 real device.

Mesh axes
---------
single-pod : (16, 16)        → ("data", "model")      — 256 chips (one v5e pod)
multi-pod  : (2, 16, 16)     → ("pod", "data", "model") — 512 chips, 2 pods

* LM training: FSDP/DP over ("pod","data"), TP/EP over "model".
* LM serving:  batch over ("pod","data"), TP over "model"; long-context decode
  additionally shards KV over "data" (split-K attention).
* CMA-ES strategies: the evaluation axis is the whole mesh flattened
  (K-Distributed heap layout over pod→data→model order); K-Replicated phases
  re-view the same devices as ("grp", "mem") via ``make_group_mesh``.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def _mk(shape, names, devices=None):
    # jax.sharding.AxisType landed after 0.4.x; older jax only has untyped axes
    kw = {}
    if hasattr(jax.sharding, "AxisType"):
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(names)
    if devices is None:
        return jax.make_mesh(shape, names, **kw)
    devs = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(devs, names, **kw)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh_for(n_devices: Optional[int] = None, model_parallel: int = 1,
                  pods: int = 1):
    """A (pod, data, model)-shaped mesh for an arbitrary device count
    (elastic scaling: checkpoint resharding accepts any such mesh)."""
    n = n_devices if n_devices is not None else len(jax.devices())
    if n % (model_parallel * pods):
        raise ValueError(f"{n} devices not divisible by mp={model_parallel}×pods={pods}")
    data = n // (model_parallel * pods)
    if pods > 1:
        return _mk((pods, data, model_parallel), ("pod", "data", "model"))
    return _mk((data, model_parallel), ("data", "model"))


def make_eval_mesh(n_devices: Optional[int] = None):
    """1-D mesh over all devices — the CMA-ES evaluation axis."""
    n = n_devices if n_devices is not None else len(jax.devices())
    return _mk((n,), ("ev",))


def make_campaign_mesh(n_devices: Optional[int] = None, devices=None):
    """1-D ("camp",) mesh — the campaign-batch axis of the mesh campaign
    engine (distributed/mesh_engine.py): (fid, instance, run) members shard
    over it, one slice per device/island.  ``devices`` carves the mesh out of
    an explicit device list (scaling curves over prefixes of the virtual-CPU
    fleet; re-viewing a production mesh's devices as one flat campaign axis).
    """
    if devices is not None:
        devices = list(devices)
        return _mk((len(devices),), ("camp",), devices=devices)
    n = n_devices if n_devices is not None else len(jax.devices())
    return _mk((n,), ("camp",))


def make_group_mesh(n_groups: int, group_size: int):
    """(grp, mem) view for one K-Replicated phase."""
    return _mk((n_groups, group_size), ("grp", "mem"))
