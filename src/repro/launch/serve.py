"""Serving launcher: batched greedy generation with the step-synchronous
engine (smoke configs on CPU; production mesh on a pod).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import lm
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if not cfg.embed_inputs or cfg.family == "vlm":
        raise SystemExit(f"{args.arch}: serve CLI demo supports token-input "
                         "archs (frontend-stub archs are covered by the "
                         "dry-run serve cells)")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, size=(args.prompt_len,),
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.batch)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    n_tok = args.batch * args.new_tokens
    print(f"[serve] {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s batched greedy)")
    for i, r in enumerate(reqs[:2]):
        print(f"  req{i}: {r.out[:12]} ...")


if __name__ == "__main__":
    main()
