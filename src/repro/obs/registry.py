"""Zero-dependency metrics runtime: the process-wide ``MetricsRegistry``.

Three instrument kinds over labeled series — ``Counter`` (monotone),
``Gauge`` (last value), ``Histogram`` (fixed log-spaced buckets from the
schema) — all created lazily on first emission and validated against
``obs/schema.py``: an unknown metric name, a wrong kind, or a wrong label
set raises at the emission site, so the code cannot emit a series the docs
don't define.

Emission is HOST-SIDE ONLY by design: every instrumented value in this repo
is a Python/NumPy scalar that already crossed the device boundary at an
existing segment-boundary pull (or a host ``perf_counter`` delta).  The
registry never touches a jax array and never forces a device sync — the
whole module imports neither jax nor numpy (asserted, together with the
unchanged sync/compile counts, in tests/test_obs.py).

Two read surfaces:

* **JSONL sink** — ``flush_jsonl(path)`` appends ONE line per flush
  ({seq, unix_s, metrics: [...]}); the campaign server calls it at every
  segment boundary when constructed with ``metrics_out=...`` (the
  ``--metrics-out`` flag of launch/serve_campaigns.py).
* **HTTP** — ``start_metrics_server()`` serves ``render_text()`` (a
  prometheus-style exposition) at ``/metrics`` from a daemon thread, for
  dashboards to scrape a long-lived service.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, Iterator, List, Optional, Tuple

from repro.obs import schema as schema_mod

LabelKey = Tuple[Tuple[str, object], ...]


class Counter:
    """Monotone accumulator.  ``inc`` with a negative value raises — a
    counter that can go down is a gauge."""

    kind = schema_mod.COUNTER
    __slots__ = ("value",)

    def __init__(self, spec):
        self.value = 0.0

    def inc(self, v: float = 1.0):
        if v < 0:
            raise ValueError(f"counter increment must be >= 0, got {v}")
        self.value += v


class Gauge:
    """Last-written value (e.g. queue depth, slot occupancy)."""

    kind = schema_mod.GAUGE
    __slots__ = ("value",)

    def __init__(self, spec):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` observations with
    ``value <= buckets[i]``, plus one implicit +Inf overflow bucket; bucket
    edges come from the metric's schema entry (log-spaced,
    ``schema.log_buckets``) so every emitter of a name shares one table."""

    kind = schema_mod.HISTOGRAM
    __slots__ = ("buckets", "counts", "sum", "count")

    def __init__(self, spec):
        self.buckets = tuple(spec.buckets)
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        v = float(v)
        self.sum += v
        self.count += 1
        for i, edge in enumerate(self.buckets):
            if v <= edge:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-resolution quantile estimate (upper edge of the bucket
        holding the q-th observation; None when empty) — a cheap SLO read
        for dashboards; the soak harness computes exact percentiles from
        raw latencies instead."""
        if not self.count:
            return None
        need = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= need and c:
                return (self.buckets[i] if i < len(self.buckets)
                        else float("inf"))
        return float("inf")


_KINDS = {schema_mod.COUNTER: Counter, schema_mod.GAUGE: Gauge,
          schema_mod.HISTOGRAM: Histogram}


class MetricsRegistry:
    """Process-wide labeled-series store, schema-validated at emission.

    ``counter/gauge/histogram(name, **labels)`` returns the live series for
    that (name, labels) pair, creating it on first use.  Thread-safe at the
    series-map level (the HTTP endpoint reads from its own thread); the
    instruments themselves are plain float updates under the GIL.
    """

    def __init__(self, specs: Optional[Dict[str, schema_mod.MetricSpec]]
                 = None):
        self.specs = schema_mod.SPECS if specs is None else specs
        self._series: Dict[Tuple[str, LabelKey], object] = {}
        self._lock = threading.Lock()
        self._flush_seq = 0

    # -- emission -------------------------------------------------------------
    def _get(self, kind: str, name: str, labels: dict):
        spec = self.specs.get(name)
        if spec is None:
            raise KeyError(f"metric {name!r} is not defined in "
                           f"repro.obs.schema.SCHEMA — add it there first")
        if spec.kind != kind:
            raise TypeError(f"metric {name!r} is a {spec.kind}, "
                            f"requested as {kind}")
        if tuple(sorted(labels)) != tuple(sorted(spec.labels)):
            raise ValueError(
                f"metric {name!r} requires labels {sorted(spec.labels)}, "
                f"got {sorted(labels)}")
        key = (name, tuple(sorted(labels.items())))
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.setdefault(key, _KINDS[kind](spec))
        return s

    def counter(self, name: str, **labels) -> Counter:
        return self._get(schema_mod.COUNTER, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(schema_mod.GAUGE, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(schema_mod.HISTOGRAM, name, labels)

    # -- read surfaces --------------------------------------------------------
    def collect(self) -> List[dict]:
        """JSON-able snapshot of every live series (deterministic order)."""
        out = []
        with self._lock:
            items = sorted(self._series.items(), key=lambda kv: kv[0])
        for (name, lkey), s in items:
            rec = {"name": name, "type": s.kind, "labels": dict(lkey)}
            if s.kind == schema_mod.HISTOGRAM:
                rec.update(count=s.count, sum=round(s.sum, 9),
                           buckets=[[le, c] for le, c in
                                    zip(list(s.buckets) + ["+Inf"],
                                        s.counts)])
            else:
                rec["value"] = s.value
            out.append(rec)
        return out

    def flush_jsonl(self, path: str):
        """Append one flush record (all live series) as a single JSON line.
        Lines carry a per-registry ``seq`` and a wall-clock ``unix_s`` so a
        soak run's file replays as a time series.  Each append is flushed
        AND fsync'd before close so a soak killed mid-run (the chaos gate's
        whole point) leaves at most one torn trailing line — which
        ``read_jsonl`` skips on replay."""
        rec = {"seq": self._flush_seq, "unix_s": round(time.time(), 3),
               "metrics": self.collect()}
        self._flush_seq += 1
        with open(path, "a") as fh:
            fh.write(json.dumps(rec) + "\n")
            fh.flush()
            os.fsync(fh.fileno())

    def render_text(self) -> str:
        """Prometheus-style text exposition (the ``/metrics`` body)."""
        by_name: Dict[str, List[Tuple[LabelKey, object]]] = {}
        with self._lock:
            for (name, lkey), s in sorted(self._series.items(),
                                          key=lambda kv: kv[0]):
                by_name.setdefault(name, []).append((lkey, s))
        lines = []
        for name, series in by_name.items():
            spec = self.specs[name]
            lines.append(f"# HELP {name} {spec.help}")
            lines.append(f"# TYPE {name} {spec.kind}")
            for lkey, s in series:
                lbl = _fmt_labels(dict(lkey))
                if s.kind == schema_mod.HISTOGRAM:
                    acc = 0
                    for le, c in zip(list(s.buckets) + ["+Inf"], s.counts):
                        acc += c
                        lbl_le = _fmt_labels({**dict(lkey), "le": le})
                        lines.append(f"{name}_bucket{lbl_le} {acc}")
                    lines.append(f"{name}_sum{lbl} {s.sum:.9g}")
                    lines.append(f"{name}_count{lbl} {s.count}")
                else:
                    lines.append(f"{name}{lbl} {s.value:.9g}")
        return "\n".join(lines) + "\n"

    def reset(self):
        with self._lock:
            self._series.clear()
            self._flush_seq = 0


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + body + "}"


def read_jsonl(path: str) -> Iterator[dict]:
    """Crash-safe JSONL reader: yield each parseable record, skipping a
    torn final line (a process killed mid-``flush_jsonl`` / mid-trace
    export).  A malformed line anywhere BUT the end raises — that is
    corruption, not a crash artifact."""
    with open(path) as fh:
        lines = fh.readlines()
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            yield json.loads(line)
        except json.JSONDecodeError:
            if i == len(lines) - 1:
                return                    # torn tail from a dying writer
            raise


# ---------------------------------------------------------------------------
# the process-wide registry
# ---------------------------------------------------------------------------

_DEFAULT: Optional[MetricsRegistry] = None
_DEFAULT_LOCK = threading.Lock()


def metrics() -> MetricsRegistry:
    """The process-wide registry every instrumented module emits to."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = MetricsRegistry()
    return _DEFAULT


def set_metrics(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide registry (tests, multi-tenant embedding);
    returns the previous one."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        prev, _DEFAULT = _DEFAULT, reg
    return prev if prev is not None else MetricsRegistry()


def reset_metrics():
    """Drop every series in the process-wide registry."""
    metrics().reset()


# ---------------------------------------------------------------------------
# HTTP /metrics endpoint (optional, in-process)
# ---------------------------------------------------------------------------

def start_metrics_server(registry: Optional[MetricsRegistry] = None,
                         host: str = "127.0.0.1", port: int = 0,
                         status_fn=None):
    """Serve ``registry.render_text()`` at ``GET /metrics`` from a daemon
    thread; returns ``(httpd, port)`` (``port=0`` binds an ephemeral port).
    Call ``httpd.shutdown()`` to stop.  Standard-library only.

    ``status_fn`` (a zero-arg callable returning a JSON-able dict) adds a
    ``GET /statusz`` introspection endpoint next to ``/metrics`` — the
    campaign server passes its ``statusz()`` (lanes, per-island occupancy
    and health grade, registry generation, queue depth, active trace
    count) so an operator can ask a live service "what are you doing"
    without parsing the prometheus exposition.  The callable runs on the
    HTTP thread: it must only read host-side state, never touch a device.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    reg = metrics() if registry is None else registry

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            route = self.path.split("?")[0]
            if route == "/statusz" and status_fn is not None:
                try:
                    body = json.dumps(status_fn(), indent=2).encode("utf-8")
                except Exception as e:       # surface, don't kill the thread
                    self.send_error(500, str(e))
                    return
                self.send_response(200)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
                return
            if route not in ("/", "/metrics"):
                self.send_error(404)
                return
            body = reg.render_text().encode("utf-8")
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *_a):        # silence per-request stderr spam
            pass

    httpd = ThreadingHTTPServer((host, port), Handler)
    thread = threading.Thread(target=httpd.serve_forever, daemon=True,
                              name="repro-obs-metrics")
    thread.start()
    return httpd, httpd.server_address[1]
