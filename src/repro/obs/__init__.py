"""Service observability: schema-validated metrics with zero dependencies.

``obs/schema.py`` is the single table every metric name, kind, label set
and histogram bucket layout is defined in (and ``docs/METRICS.md`` is
generated from); ``obs/registry.py`` is the runtime — counters, gauges,
log-bucketed histograms on a process-wide ``MetricsRegistry``, a JSONL
sink flushed at segment boundaries, and an optional in-process HTTP
``/metrics`` + ``/statusz`` endpoint.  ``obs/trace.py`` adds the causal
layer — ring-buffered spans on a process-wide ``Tracer`` with JSONL and
Chrome/Perfetto exports — and ``obs/recorder.py`` the flight recorder
(per-island last-K boundary ring, post-mortem dumps on failure).
Instrumentation is host-side only: emitters pass scalars that already
crossed the device boundary at an existing segment-boundary pull, never
jax arrays (tests/test_obs.py pins both the device-sync count and the
segment-compile count against it).
"""
from repro.obs.registry import (Counter, Gauge, Histogram,     # noqa: F401
                                MetricsRegistry, metrics, read_jsonl,
                                reset_metrics, set_metrics,
                                start_metrics_server)
from repro.obs.schema import (SCHEMA, SPECS, MetricSpec,       # noqa: F401
                              log_buckets, render_markdown)
from repro.obs.trace import (Span, Tracer, reset_tracer,       # noqa: F401
                             set_tracer, to_chrome, tracer,
                             validate_chrome)
from repro.obs.recorder import (FlightRecorder, recorder,      # noqa: F401
                                reset_recorder, set_recorder)
