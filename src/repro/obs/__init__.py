"""Service observability: schema-validated metrics with zero dependencies.

``obs/schema.py`` is the single table every metric name, kind, label set
and histogram bucket layout is defined in (and ``docs/METRICS.md`` is
generated from); ``obs/registry.py`` is the runtime — counters, gauges,
log-bucketed histograms on a process-wide ``MetricsRegistry``, a JSONL
sink flushed at segment boundaries, and an optional in-process HTTP
``/metrics`` endpoint.  Instrumentation is host-side only: emitters pass
scalars that already crossed the device boundary at an existing
segment-boundary pull, never jax arrays (tests/test_obs.py pins both the
device-sync count and the segment-compile count against it).
"""
from repro.obs.registry import (Counter, Gauge, Histogram,     # noqa: F401
                                MetricsRegistry, metrics, reset_metrics,
                                set_metrics, start_metrics_server)
from repro.obs.schema import (SCHEMA, SPECS, MetricSpec,       # noqa: F401
                              log_buckets, render_markdown)
