"""Flight recorder: a bounded per-island ring of boundary observations,
dumped as a post-mortem artifact when supervision declares an island dead
or a job is quarantined.

Every segment-boundary pull already surfaces (host-side) the island's
wall, its summed feval watermark, and — on the service path — the per-row
verdicts; the fleet layer adds a health grade.  ``FlightRecorder.observe``
keeps the last K of those per island, so when ``FleetController``/
``IslandSupervisor`` fail an island (or the server quarantines a job) the
dump is a readable last-K-boundaries timeline instead of a bare "chaos
gate failed": ``postmortem-<island>-<boundary>.json`` holding the trigger,
the timeline, and the most recent trace spans touching that island.

Dumps are opt-in: nothing is written until ``out_dir`` is configured
(``--postmortem-dir`` on bench_service.py, ``postmortem_dir`` on
``FleetConfig``); ``dump`` always returns the record so in-process callers
(tests, the chaos gate) can assert on the timeline without touching disk.
Like the rest of the obs package this module is stdlib-only and never
sees a jax array — observations are scalars that already crossed at the
existing boundary pull.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, List, Optional

from repro.obs import registry as _registry
from repro.obs import trace as _trace

#: default ring depth: enough boundaries to cover detection latency
#: (deadline + stall windows are single-digit boundaries) with context.
DEFAULT_K = 16


class FlightRecorder:
    """Per-island bounded observation ring + post-mortem dumper."""

    def __init__(self, k: int = DEFAULT_K, out_dir: Optional[str] = None):
        self.k = int(k)
        self.out_dir = out_dir
        self._lock = threading.Lock()
        self._rings: Dict[str, List[dict]] = {}
        self.dumps = 0

    # -- feed -----------------------------------------------------------------
    def observe(self, island, boundary: int, **fields):
        """Record one boundary observation for ``island`` (wall, fevals
        delta, health grade, verdicts, ... — any JSON-able host scalars).
        O(1): the ring holds the newest K records."""
        rec = {"island": island, "boundary": int(boundary),
               "unix_s": round(time.time(), 3), **fields}
        key = str(island)
        with self._lock:
            ring = self._rings.setdefault(key, [])
            ring.append(rec)
            if len(ring) > self.k:
                del ring[0]
        _registry.metrics().counter("obs_recorder_observations_total",
                                    island=str(island)).inc()
        return rec

    def last(self, island) -> List[dict]:
        with self._lock:
            return list(self._rings.get(str(island), ()))

    def reset(self):
        with self._lock:
            self._rings.clear()
            self.dumps = 0

    # -- dump -----------------------------------------------------------------
    def dump(self, island, boundary: int, trigger: str,
             extra: Optional[dict] = None,
             out_dir: Optional[str] = None) -> dict:
        """Assemble (and, when an out_dir is configured, write) the
        post-mortem for ``island`` at ``boundary``: trigger ∈
        {dead, quarantine, ...}, the last-K timeline, and the newest
        finished trace spans attributed to that island.  Returns the
        record; the written path (if any) is in ``record["path"]``."""
        spans = [s.to_json() for s in _trace.tracer().finished()
                 if str(s.attrs.get("island")) == str(island)][-self.k:]
        rec = {"island": island, "boundary": int(boundary),
               "trigger": trigger, "unix_s": round(time.time(), 3),
               "timeline": self.last(island), "spans": spans,
               "extra": extra or {}}
        _registry.metrics().counter("obs_recorder_postmortems_total",
                                    trigger=trigger).inc()
        self.dumps += 1
        d = self.out_dir if out_dir is None else out_dir
        if d:
            os.makedirs(d, exist_ok=True)
            path = os.path.join(
                d, f"postmortem-{island}-{int(boundary)}.json")
            with open(path, "w") as fh:
                json.dump(rec, fh, indent=2)
                fh.flush()
                os.fsync(fh.fileno())
            rec["path"] = path
        return rec


# ---------------------------------------------------------------------------
# the process-wide recorder
# ---------------------------------------------------------------------------

_DEFAULT: Optional[FlightRecorder] = None
_DEFAULT_LOCK = threading.Lock()


def recorder() -> FlightRecorder:
    """The process-wide flight recorder the boundary pulls feed."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = FlightRecorder()
    return _DEFAULT


def set_recorder(rec: FlightRecorder) -> FlightRecorder:
    """Swap the process-wide recorder (tests); returns the previous one."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        prev, _DEFAULT = _DEFAULT, rec
    return prev if prev is not None else FlightRecorder()


def reset_recorder():
    """Drop every ring in the process-wide recorder."""
    recorder().reset()
