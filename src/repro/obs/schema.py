"""THE metric-name table — every series the repo emits is defined here.

One ``MetricSpec`` per metric: name, kind (counter / gauge / histogram),
unit, the exact label keys every emission must carry, the emission point,
and a one-line meaning.  ``MetricsRegistry`` (obs/registry.py) refuses any
name or label set not in this table, and ``docs/METRICS.md`` embeds the
table rendered by ``render_markdown`` between markers — so code, registry
and docs cannot drift:

  PYTHONPATH=src python -m repro.obs.schema --check docs/METRICS.md   # CI lint
  PYTHONPATH=src python -m repro.obs.schema --write docs/METRICS.md   # refresh

This module is deliberately jax-free (the drift check must not pay a jax
import), and the whole obs package has zero third-party dependencies.

Naming follows the prometheus conventions production governance services
front their metrics with: snake_case, ``_total`` suffix on counters,
``_s`` suffix on second-valued series, subsystem prefix first
(``bucketed_`` the segment driver, ``mesh_`` the S1/S2 mesh engine,
``service_`` the campaign server, ``fleet_`` the supervision layer).  Restart-policy-adjacent names carry a
``policy``-free shape on purpose: when BIPOP & friends (arXiv 1207.0206)
and large-scale strategy tiers (arXiv 2310.05377) land as per-row restart
policies, they extend these series with a ``policy`` label instead of
inventing parallel names.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
from typing import Dict, Tuple

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"


def log_buckets(lo: float, hi: float, per_decade: int = 2,
                ) -> Tuple[float, ...]:
    """Fixed log-spaced histogram upper edges from ``lo`` to ``hi``
    inclusive, ``per_decade`` edges per decade.  Edges are rounded to 6
    significant digits so the schema (and therefore the JSONL sink and the
    docs) is reproducible across platforms."""
    import math
    n = int(round(math.log10(hi / lo) * per_decade))
    return tuple(float(f"{lo * 10 ** (i / per_decade):.6g}")
                 for i in range(n + 1))


#: default edges for second-valued histograms: 10 µs .. 1000 s, 2/decade —
#: wide enough to hold a sub-ms host sync and a multi-minute soak job in
#: the same fixed table (values beyond the last edge land in +Inf).
TIME_BUCKETS_S = log_buckets(1e-5, 1e3, per_decade=2)

#: edges for evaluation-count histograms (fleet lost-work accounting):
#: 1 .. 1e6 evals, one edge per decade — recovery loses whole segments, so
#: decade resolution is plenty and the table stays 7 cells wide.
EVAL_BUCKETS = log_buckets(1, 1e6, per_decade=1)


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One metric's contract: everything an emitter and a reader share."""

    name: str
    kind: str                       # COUNTER | GAUGE | HISTOGRAM
    unit: str                       # "s", "evaluations", "jobs", ...
    labels: Tuple[str, ...]         # exact label keys, enforced at emission
    emitted_by: str                 # module:function of the emission point
    help: str                       # one-line meaning
    buckets: Tuple[float, ...] = () # histogram upper edges (+Inf implied)

    def __post_init__(self):
        if self.kind not in (COUNTER, GAUGE, HISTOGRAM):
            raise ValueError(f"unknown metric kind {self.kind!r}")
        if self.kind == HISTOGRAM and not self.buckets:
            object.__setattr__(self, "buckets", TIME_BUCKETS_S)
        if self.kind != HISTOGRAM and self.buckets:
            raise ValueError(f"{self.name}: buckets only apply to histograms")


SCHEMA: Tuple[MetricSpec, ...] = (
    # -- bucketed segment driver (core/bucketed.py:drive_segments) ----------
    MetricSpec("bucketed_segments_total", COUNTER, "segments", ("bucket",),
               "core/bucketed.py:drive_segments",
               "Dispatched bucket segments, by rung bucket."),
    MetricSpec("bucketed_segment_wall_s", HISTOGRAM, "s", ("bucket",),
               "core/bucketed.py:drive_segments",
               "Per-segment wall: dispatch+block unoverlapped, dispatch-only "
               "when overlap=True (the block rides the next sync)."),
    MetricSpec("bucketed_sync_s", HISTOGRAM, "s", (),
               "core/bucketed.py:drive_segments",
               "Boundary host sync: the ONE batched schedule pull "
               "(pull_schedule / pull_schedule_allgather) per segment."),
    MetricSpec("bucketed_spec_dispatch_total", COUNTER, "segments",
               ("outcome",),
               "core/bucketed.py:drive_segments",
               "Speculative double-buffered dispatches, outcome=hit|miss "
               "(miss = bucket changed, speculative output discarded)."),
    MetricSpec("bucketed_useful_evals_total", COUNTER, "evaluations", (),
               "core/bucketed.py:drive_segments",
               "True fitness evaluations progressed between boundary pulls "
               "(delta of the pulled per-member budget counters)."),
    MetricSpec("bucketed_padded_evals_total", COUNTER, "evaluations",
               ("bucket",),
               "core/bucketed.py:drive_segments",
               "Device evaluation rows paid per dispatched segment "
               "(rows x gens x lambda_bucket); padding waste = "
               "padded/useful."),
    MetricSpec("bucketed_eigh_blocks_total", COUNTER, "blocks", ("bucket",),
               "core/bucketed.py:drive_segments",
               "Batched eigendecomposition blocks executed "
               "(seg_gens/eigen_interval per dispatched segment)."),
    MetricSpec("bucketed_eval_fused_generations_total", COUNTER,
               "generations", (),
               "core/bucketed.py:run_campaign_bucketed",
               "Generations dispatched through the eval-fused sample "
               "epilogue (whole fid menu separable and REPRO_EVAL_FUSION "
               "on): fitness computed in the sample kernel, X never "
               "materialized in HBM."),
    # -- mesh engine S1/S2 (distributed/mesh_engine.py) ---------------------
    MetricSpec("mesh_island_dispatch_s", HISTOGRAM, "s",
               ("strategy", "island"),
               "distributed/mesh_engine.py:_drive_concurrent/_drive_ordered",
               "Per-island segment dispatch wall (async enqueue for S2 "
               "islands; island=all for S1's whole-mesh program)."),
    MetricSpec("mesh_island_block_s", HISTOGRAM, "s", ("island",),
               "distributed/mesh_engine.py:_drive_concurrent",
               "S2 per-island blocking schedule pull — where an island "
               "waits on its own running segment."),
    MetricSpec("mesh_exchange_s", HISTOGRAM, "s", ("strategy",),
               "distributed/mesh_engine.py:_drive_concurrent/_drive_ordered",
               "Scalar exchange latency: S1 folds the psum'd budget/best "
               "outputs lazily at the boundary pull (they are ready by "
               "then), S2 folds the per-island host scalars."),
    MetricSpec("mesh_exchange_rounds_total", COUNTER, "rounds",
               ("strategy",),
               "distributed/mesh_engine.py:_drive_concurrent/_drive_ordered",
               "Completed cross-island exchange rounds."),
    MetricSpec("mesh_retirements_total", COUNTER, "islands", ("reason",),
               "distributed/mesh_engine.py:_drive_concurrent",
               "Island retirement events, reason=target (stop_at early "
               "sharing) | exhausted (no member can pay a generation)."),
    # -- campaign service (service/server.py) -------------------------------
    MetricSpec("service_jobs_total", COUNTER, "jobs", ("event",),
               "service/server.py:submit/_admit/_finalize/drain",
               "Job lifecycle events: event=submitted|admitted|completed|"
               "rejected|cancelled|expired|quarantined|shed."),
    MetricSpec("service_job_lifecycle_total", COUNTER, "transitions",
               ("from", "to"),
               "service/server.py:_transition/submit/_settle_shed",
               "Request state-machine edges (new->queued, queued->running, "
               "running->done/cancelled/expired/quarantined, "
               "queued->shed/...): every transition increments exactly one "
               "(from, to) series."),
    MetricSpec("service_shed_total", COUNTER, "jobs", (),
               "service/server.py:_settle_shed",
               "Pending tickets evicted by priority-aware load shedding (a "
               "full queue displaced its lowest-priority entry for a "
               "strictly higher-priority submit)."),
    MetricSpec("service_quarantine_total", COUNTER, "jobs", ("reason",),
               "service/server.py:_finalize",
               "Poison jobs quarantined at a boundary pull, reason="
               "nonfinite (NaN/inf best_f after real evaluations) | "
               "no_progress (flat per-row feval watermark over dispatched "
               "boundaries)."),
    MetricSpec("service_registry_generation", GAUGE, "generation", (),
               "service/server.py:step",
               "Current FitnessRegistry generation: bumps when a callable "
               "is registered on a live server (versioned rollout; new "
               "lanes compile against the new generation, resident lanes "
               "keep running untouched)."),
    MetricSpec("service_queue_depth", GAUGE, "jobs", (),
               "service/server.py:step",
               "Pending admission-queue depth at the end of a service "
               "round."),
    MetricSpec("service_admission_wait_s", HISTOGRAM, "s", (),
               "service/server.py:_admit",
               "submit -> admitted-into-a-row wait (queue time)."),
    MetricSpec("service_time_to_first_ticket_s", HISTOGRAM, "s", (),
               "service/server.py:_island_boundary",
               "submit -> first streamed ticket update."),
    MetricSpec("service_time_to_completion_s", HISTOGRAM, "s", (),
               "service/server.py:_finalize",
               "submit -> done: the per-job completion latency the soak "
               "SLO is written against."),
    MetricSpec("service_slot_occupancy", GAUGE, "fraction",
               ("lane", "island"),
               "service/server.py:step",
               "Occupied fraction of an island's member rows (per-lane "
               "slot occupancy)."),
    MetricSpec("service_boundary_pull_s", HISTOGRAM, "s", ("lane",),
               "service/server.py:_island_boundary",
               "Per-island boundary schedule pull (the service's only "
               "blocking device sync)."),
    MetricSpec("service_segments_total", COUNTER, "segments",
               ("lane", "bucket"),
               "service/server.py:_island_boundary",
               "Island segments dispatched by the service loop."),
    MetricSpec("service_program_cache_hit_rate", GAUGE, "fraction", (),
               "service/server.py:step",
               "Process-wide segment ProgramCache hits/(hits+traces)."),
    MetricSpec("service_snapshot_s", HISTOGRAM, "s", (),
               "service/server.py:snapshot",
               "Wall time of one snapshot() commit."),
    MetricSpec("service_boundaries_total", COUNTER, "rounds", (),
               "service/server.py:step",
               "Completed service rounds (one segment boundary per island "
               "per round)."),
    # -- fleet supervision (fleet/health.py, fleet/controller.py) -----------
    MetricSpec("fleet_island_state", GAUGE, "state", ("island",),
               "fleet/health.py:FleetHealth._set",
               "Island health state gauge: 0=alive, 1=suspect, 2=dead "
               "(emitted on every state transition)."),
    MetricSpec("fleet_failures_total", COUNTER, "islands", ("reason",),
               "fleet/controller.py:IslandSupervisor/_fail_island",
               "Island failure events, reason=killed (fault plan) | "
               "deadline (pull wall over budget) | stalled (no eval "
               "progress while dispatched)."),
    MetricSpec("fleet_recoveries_total", COUNTER, "recoveries", ("mode",),
               "fleet/controller.py:IslandSupervisor/_fail_island/_rejoin",
               "Recovery actions: mode=replayed (engine restored from "
               "snapshot in place) | reassigned (row re-placed on a "
               "survivor) | requeued (no capacity, parked for later) | "
               "rejoined (island re-admitted after down_for)."),
    MetricSpec("fleet_recovery_wall_s", HISTOGRAM, "s", (),
               "fleet/controller.py:IslandSupervisor/_fail_island",
               "Wall time of one failure-to-recovered handling pass "
               "(snapshot load + re-placement)."),
    MetricSpec("fleet_lost_work_evals", HISTOGRAM, "evaluations", (),
               "fleet/controller.py:IslandSupervisor/_fail_island",
               "Fitness evaluations discarded per failure: progress past "
               "the last snapshot that must be re-run (bounds the "
               "snapshot-cadence / lost-work trade).",
               buckets=EVAL_BUCKETS),
    MetricSpec("fleet_pull_retries_total", COUNTER, "retries", ("island",),
               "fleet/controller.py:IslandSupervisor.pull",
               "Boundary pulls re-issued after a corrupt read (regressed "
               "eval counters)."),
    MetricSpec("fleet_rebalances_total", COUNTER, "repacks", ("trigger",),
               "fleet/controller.py:FleetController._maybe_rebalance",
               "Cross-island lane repacks scheduled by the controller, "
               "trigger=skew (occupancy imbalance) | rejoin (island "
               "re-admitted)."),
    # -- causal tracing + flight recorder (obs/trace.py, obs/recorder.py) ---
    MetricSpec("service_trace_spans_total", COUNTER, "spans", ("span",),
               "obs/trace.py:Tracer.end",
               "Finished spans appended to the process-wide tracer ring, "
               "by span name (job|queued|running|recover|segment|pull|"
               "dispatch|block|compile|...)."),
    MetricSpec("service_trace_active", GAUGE, "spans", (),
               "obs/trace.py:Tracer.start/end",
               "Currently open (started, not yet ended) spans — exposed "
               "on /statusz as the live-trace count."),
    MetricSpec("service_trace_dropped_total", COUNTER, "spans", (),
               "obs/trace.py:Tracer.end",
               "Finished spans evicted from the bounded tracer ring "
               "(capacity overflow on a long soak; raise Tracer capacity "
               "or export more often)."),
    MetricSpec("obs_recorder_observations_total", COUNTER, "observations",
               ("island",),
               "obs/recorder.py:FlightRecorder.observe",
               "Boundary observations fed into the per-island flight-"
               "recorder ring (wall, fevals delta, health grade, "
               "verdicts)."),
    MetricSpec("obs_recorder_postmortems_total", COUNTER, "dumps",
               ("trigger",),
               "obs/recorder.py:FlightRecorder.dump",
               "Post-mortem dumps assembled on failure, trigger=dead "
               "(island graded DEAD by fleet supervision) | quarantine "
               "(poison job pulled from a row)."),
)

SPECS: Dict[str, MetricSpec] = {s.name: s for s in SCHEMA}
assert len(SPECS) == len(SCHEMA), "duplicate metric name in SCHEMA"


# ---------------------------------------------------------------------------
# docs generation + drift check
# ---------------------------------------------------------------------------

BEGIN_MARK = "<!-- BEGIN GENERATED TABLE: repro.obs.schema (do not edit) -->"
END_MARK = "<!-- END GENERATED TABLE -->"


def render_markdown() -> str:
    """The METRICS.md reference table, one row per metric."""
    lines = [
        "| name | type | labels | unit | emitted by | meaning |",
        "|---|---|---|---|---|---|",
    ]
    for s in SCHEMA:
        labels = ", ".join(f"`{v}`" for v in s.labels) or "—"
        help_md = s.help.replace("|", "\\|")     # keep table cells intact
        lines.append(f"| `{s.name}` | {s.kind} | {labels} | {s.unit} "
                     f"| `{s.emitted_by}` | {help_md} |")
    return "\n".join(lines)


def _splice(text: str) -> str:
    """Replace the marked block of a METRICS.md body with the current table;
    raises if the markers are missing."""
    b, e = text.find(BEGIN_MARK), text.find(END_MARK)
    if b < 0 or e < 0 or e < b:
        raise ValueError(f"markers {BEGIN_MARK!r} / {END_MARK!r} not found")
    return (text[:b + len(BEGIN_MARK)] + "\n" + render_markdown() + "\n"
            + text[e:])


def check_file(path: str) -> bool:
    """True iff the generated block in ``path`` matches the live schema."""
    with open(path) as fh:
        text = fh.read()
    return _splice(text) == text


def write_file(path: str):
    with open(path) as fh:
        text = fh.read()
    with open(path, "w") as fh:
        fh.write(_splice(text))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", metavar="METRICS_MD", default=None,
                    help="exit 1 if the file's generated table is stale")
    ap.add_argument("--write", metavar="METRICS_MD", default=None,
                    help="refresh the file's generated table in place")
    args = ap.parse_args(argv)
    if args.write:
        write_file(args.write)
        print(f"[obs.schema] refreshed {args.write}")
        return 0
    if args.check:
        if check_file(args.check):
            print(f"[obs.schema] {args.check} matches the schema")
            return 0
        # show WHAT drifted, not just that it did: unified diff of the
        # file as-is vs the file with the generated block refreshed.
        import difflib
        with open(args.check) as fh:
            current = fh.read()
        diff = difflib.unified_diff(
            current.splitlines(keepends=True),
            _splice(current).splitlines(keepends=True),
            fromfile=f"{args.check} (on disk)",
            tofile=f"{args.check} (from schema)")
        sys.stderr.writelines(diff)
        print(f"[obs.schema] {args.check} is STALE — regenerate with:\n"
              f"  PYTHONPATH=src python -m repro.obs.schema --write "
              f"{args.check}", file=sys.stderr)
        return 1
    ap.error("pass --check or --write")


if __name__ == "__main__":
    sys.exit(main())
