"""Zero-dependency causal tracing: spans, the process-wide ``Tracer``,
and Perfetto export.

Where obs/registry.py answers "how much / how often" with aggregate
counters and histograms, this module answers "what happened to THIS job"
and "where did island 3's wall time go": explicit-start/end spans on a
monotonic clock, ring-buffered on a process-wide ``Tracer`` that the
service/engine vertical feeds at the SAME existing host boundaries the
metrics layer uses.  The zero-overhead contract is identical — a span
carries only Python scalars that already crossed the device boundary at a
segment-boundary pull (or host ``perf_counter`` deltas), so tracing adds
zero device syncs and zero compiled programs (pinned, with the metrics
pins, in tests).

Span model
----------

``Span(trace_id, span_id, parent_id, name, t0, t1, attrs)`` — ``t0/t1``
are ``time.perf_counter()`` readings (the tracer records a wall-clock
anchor at construction so exports can surface unix time).  A job's root
span ("job") is started at submit and ended at its terminal lifecycle
edge; its children ("queued", "running", "recover") chain through
``parent_id`` so a recovered job's pre- and post-failure activity share
one trace.  Island-side spans ("segment", "pull", "dispatch", "block",
"compile") carry ``island``/``lane`` attrs and render as per-island lane
tracks.

Read surfaces
-------------

* ``export_jsonl(path)`` — one JSON line per finished span (fsync'd), the
  input format of the offline digest:
  ``python -m repro.obs.trace --summarize trace.jsonl``
  (critical path per job, per-island busy/blocked/idle fractions).
* ``export_chrome(path)`` — Chrome/Perfetto ``trace_event`` JSON
  (``--trace-out`` on serve_campaigns.py / bench_service.py): open the
  file directly in https://ui.perfetto.dev — one lane track per island,
  one async track per job.

Like the registry, this module is stdlib-only (no jax, no numpy; asserted
in tests/test_obs.py's hermetic import pin) and mirrors the
``metrics()/set_metrics()/reset_metrics()`` process-wide singleton with
``tracer()/set_tracer()/reset_tracer()``.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import os
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple, Union

from repro.obs import registry as _registry

#: span names whose wall counts as "busy" vs "blocked" in the offline
#: per-island digest (everything else on an island track is neutral).
BUSY_NAMES = ("segment", "dispatch", "compile")
BLOCKED_NAMES = ("pull", "block", "sync", "exchange")


@dataclasses.dataclass
class Span:
    """One timed region.  ``t0``/``t1`` are monotonic ``perf_counter``
    readings; ``t1 is None`` while the span is open.  ``attrs`` holds only
    JSON-able host scalars (enforced at export, not at set — emission must
    stay allocation-cheap)."""

    trace_id: int
    span_id: int
    parent_id: Optional[int]
    name: str
    t0: float
    t1: Optional[float] = None
    attrs: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def dur(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def to_json(self) -> dict:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "name": self.name,
                "t0": round(self.t0, 9), "t1": round(self.t1, 9),
                "dur_s": round(self.dur, 9), "attrs": self.attrs}


class Tracer:
    """Process-wide ring-buffered span store with explicit start/end.

    Thread-safe: starts/ends from the service loop and the metrics HTTP
    thread interleave under one lock.  Finished spans live in a bounded
    ring (oldest evicted first, eviction counted) so a week-long soak
    cannot grow host memory; exports and the flight recorder read the
    ring, they never block emission.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: List[Span] = []
        self._open: Dict[int, Span] = {}
        self._next_id = 1
        self.dropped = 0
        # wall anchor: perf_counter t maps to unix epoch_unix+(t-epoch_perf)
        self.epoch_unix = time.time()
        self.epoch_perf = time.perf_counter()

    # -- emission -------------------------------------------------------------
    def start(self, name: str, parent: Union[Span, int, None] = None,
              trace_id: Optional[int] = None, **attrs) -> Span:
        """Open a span.  ``parent`` (a Span or span_id) links the causal
        chain; ``trace_id`` defaults to the parent's trace (or a fresh one
        for roots)."""
        t0 = time.perf_counter()
        parent_id = parent.span_id if isinstance(parent, Span) else parent
        with self._lock:
            sid = self._next_id
            self._next_id += 1
            if trace_id is None:
                trace_id = (parent.trace_id if isinstance(parent, Span)
                            else sid)
            s = Span(trace_id=trace_id, span_id=sid, parent_id=parent_id,
                     name=name, t0=t0, attrs=dict(attrs))
            self._open[sid] = s
        reg = _registry.metrics()
        reg.gauge("service_trace_active").set(len(self._open))
        return s

    def end(self, span: Span, **attrs) -> Span:
        """Close a span; extra ``attrs`` merge over the start-time ones
        (terminal status, reasons, hit/miss outcomes land here)."""
        span.t1 = time.perf_counter()
        if attrs:
            span.attrs.update(attrs)
        with self._lock:
            self._open.pop(span.span_id, None)
            if len(self._ring) >= self.capacity:
                del self._ring[0]
                self.dropped += 1
                _registry.metrics().counter(
                    "service_trace_dropped_total").inc()
            self._ring.append(span)
        reg = _registry.metrics()
        reg.counter("service_trace_spans_total", span=span.name).inc()
        reg.gauge("service_trace_active").set(len(self._open))
        return span

    @contextlib.contextmanager
    def span(self, name: str, parent: Union[Span, int, None] = None,
             trace_id: Optional[int] = None, **attrs):
        s = self.start(name, parent=parent, trace_id=trace_id, **attrs)
        try:
            yield s
        finally:
            if s.t1 is None:
                self.end(s)

    def event(self, name: str, parent: Union[Span, int, None] = None,
              trace_id: Optional[int] = None, **attrs) -> Span:
        """Instantaneous marker (t0 == t1) — health transitions, kills."""
        s = self.start(name, parent=parent, trace_id=trace_id, **attrs)
        return self.end(s)

    # -- read surfaces --------------------------------------------------------
    def finished(self) -> List[Span]:
        with self._lock:
            return list(self._ring)

    def active_count(self) -> int:
        with self._lock:
            return len(self._open)

    def unix(self, t: float) -> float:
        """Map a span perf_counter reading to unix wall time."""
        return self.epoch_unix + (t - self.epoch_perf)

    def reset(self):
        with self._lock:
            self._ring.clear()
            self._open.clear()
            self._next_id = 1
            self.dropped = 0
            self.epoch_unix = time.time()
            self.epoch_perf = time.perf_counter()

    # -- exports --------------------------------------------------------------
    def export_jsonl(self, path: str) -> int:
        """Write every finished span as one JSON line (fsync'd on close,
        same durability contract as ``MetricsRegistry.flush_jsonl``);
        returns the span count."""
        spans = self.finished()
        with open(path, "w") as fh:
            for s in spans:
                fh.write(json.dumps(s.to_json()) + "\n")
            fh.flush()
            os.fsync(fh.fileno())
        return len(spans)

    def export_chrome(self, path: str) -> int:
        """Write Chrome/Perfetto ``trace_event`` JSON: job roots and their
        lifecycle children as async ("b"/"e") events — one per-job track —
        island-attributed spans as complete ("X") events on one lane track
        per (lane, island), everything else on a host track."""
        obj = to_chrome(self.finished(), epoch_perf=self.epoch_perf)
        body = json.dumps(obj)
        with open(path, "w") as fh:
            fh.write(body)
            fh.flush()
            os.fsync(fh.fileno())
        return len(obj["traceEvents"])


# ---------------------------------------------------------------------------
# the process-wide tracer
# ---------------------------------------------------------------------------

_DEFAULT: Optional[Tracer] = None
_DEFAULT_LOCK = threading.Lock()


def tracer() -> Tracer:
    """The process-wide tracer every instrumented module emits to."""
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = Tracer()
    return _DEFAULT


def set_tracer(tr: Tracer) -> Tracer:
    """Swap the process-wide tracer (tests, embedding); returns the
    previous one."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        prev, _DEFAULT = _DEFAULT, tr
    return prev if prev is not None else Tracer()


def reset_tracer():
    """Drop every span in the process-wide tracer."""
    tracer().reset()


# ---------------------------------------------------------------------------
# Chrome/Perfetto trace_event assembly + schema validation
# ---------------------------------------------------------------------------

HOST_PID, ISLAND_PID, JOB_PID = 1, 2, 3


def _island_tid_key(s: Span) -> Tuple[str, str]:
    return (str(s.attrs.get("lane", "")), str(s.attrs.get("island", "")))


def to_chrome(spans: List[Span], epoch_perf: float = 0.0) -> dict:
    """Assemble the ``trace_event`` object for a span list (pure — no
    tracer state), timestamps in µs relative to ``epoch_perf``."""
    def us(t):
        return round((t - epoch_perf) * 1e6, 3)

    events: List[dict] = []
    island_tids: Dict[Tuple[str, str], int] = {}
    job_tracks = 0
    for s in spans:
        if s.t1 is None:
            continue
        if "job" in s.attrs and "island" not in s.attrs:
            jid = f"job:{s.trace_id:x}"
            base = {"cat": "job", "id": jid, "pid": JOB_PID, "tid": 0,
                    "name": s.name}
            events.append({**base, "ph": "b", "ts": us(s.t0),
                           "args": s.attrs})
            events.append({**base, "ph": "e", "ts": us(s.t1)})
            job_tracks += 1
        elif "island" in s.attrs:
            key = _island_tid_key(s)
            tid = island_tids.setdefault(key, len(island_tids))
            events.append({"ph": "X", "cat": "island", "name": s.name,
                           "pid": ISLAND_PID, "tid": tid, "ts": us(s.t0),
                           "dur": us(s.t1) - us(s.t0), "args": s.attrs})
        else:
            events.append({"ph": "X", "cat": "host", "name": s.name,
                           "pid": HOST_PID, "tid": 0, "ts": us(s.t0),
                           "dur": us(s.t1) - us(s.t0), "args": s.attrs})
    meta = [
        {"ph": "M", "name": "process_name", "pid": HOST_PID,
         "args": {"name": "host"}},
        {"ph": "M", "name": "process_name", "pid": ISLAND_PID,
         "args": {"name": "islands"}},
        {"ph": "M", "name": "process_name", "pid": JOB_PID,
         "args": {"name": "jobs"}},
    ]
    for (lane, island), tid in sorted(island_tids.items(),
                                      key=lambda kv: kv[1]):
        label = (f"{lane}/island {island}" if lane
                 else f"island {island}")
        meta.append({"ph": "M", "name": "thread_name", "pid": ISLAND_PID,
                     "tid": tid, "args": {"name": label}})
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"spans": sum(1 for s in spans
                                       if s.t1 is not None),
                          "job_tracks": job_tracks}}


def validate_chrome(obj: dict) -> List[str]:
    """Schema-check a ``trace_event`` object; returns a list of problems
    (empty == valid).  Used by the chaos gate and the trace tests so a
    malformed export fails CI instead of failing silently in the UI."""
    errs: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing top-level traceEvents list"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(evs):
        ph = ev.get("ph")
        if ph not in ("X", "b", "e", "M"):
            errs.append(f"event {i}: unknown ph {ph!r}")
            continue
        if "name" not in ev or "pid" not in ev:
            errs.append(f"event {i}: missing name/pid")
            continue
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"event {i}: non-numeric ts")
        if ph == "X" and not (isinstance(ev.get("dur"), (int, float))
                              and ev["dur"] >= 0):
            errs.append(f"event {i}: X event needs dur >= 0")
        if ph in ("b", "e") and ("id" not in ev or "cat" not in ev):
            errs.append(f"event {i}: async event needs id and cat")
    return errs


# ---------------------------------------------------------------------------
# offline digest (--summarize)
# ---------------------------------------------------------------------------

def load_jsonl(path: str) -> List[dict]:
    """Read a span JSONL file, tolerating a truncated final line (a killed
    process mid-write) — same crash-safe contract as the metrics sink."""
    return list(_registry.read_jsonl(path))


def summarize(spans: List[dict]) -> dict:
    """Offline trace digest: per-job critical path (the sequential chain
    of lifecycle children under each "job" root) and per-island
    busy/blocked/idle fractions over the island's observed window."""
    by_id = {s["span_id"]: s for s in spans}
    children: Dict[int, List[dict]] = {}
    for s in spans:
        if s.get("parent_id") is not None:
            children.setdefault(s["parent_id"], []).append(s)

    jobs = []
    for s in spans:
        if s["name"] != "job":
            continue
        kids = sorted(children.get(s["span_id"], []),
                      key=lambda c: c["t0"])
        phases = {}
        for c in kids:
            phases[c["name"]] = round(
                phases.get(c["name"], 0.0) + c["dur_s"], 9)
        jobs.append({"job": s["attrs"].get("job"),
                     "trace_id": s["trace_id"],
                     "status": s["attrs"].get("status"),
                     "total_s": s["dur_s"],
                     "critical_path_s": round(
                         sum(c["dur_s"] for c in kids), 9),
                     "phases": phases})

    islands: Dict[str, dict] = {}
    for s in spans:
        isl = s["attrs"].get("island")
        if isl is None:
            continue
        key = str(isl)
        rec = islands.setdefault(
            key, {"busy_s": 0.0, "blocked_s": 0.0,
                  "t_lo": s["t0"], "t_hi": s["t1"], "spans": 0})
        rec["spans"] += 1
        rec["t_lo"] = min(rec["t_lo"], s["t0"])
        rec["t_hi"] = max(rec["t_hi"], s["t1"])
        if s["name"] in BUSY_NAMES:
            rec["busy_s"] += s["dur_s"]
        elif s["name"] in BLOCKED_NAMES:
            rec["blocked_s"] += s["dur_s"]
    for key, rec in islands.items():
        window = max(rec["t_hi"] - rec["t_lo"], 1e-12)
        busy, blocked = rec["busy_s"], rec["blocked_s"]
        idle = max(window - busy - blocked, 0.0)
        rec.update(window_s=round(window, 9),
                   busy_frac=round(busy / window, 6),
                   blocked_frac=round(blocked / window, 6),
                   idle_frac=round(idle / window, 6),
                   busy_s=round(busy, 9), blocked_s=round(blocked, 9))
        rec.pop("t_lo"), rec.pop("t_hi")

    return {"spans": len(spans),
            "traces": len({s["trace_id"] for s in spans}),
            "open_parents_missing": sorted(
                {s["parent_id"] for s in spans
                 if s.get("parent_id") is not None
                 and s["parent_id"] not in by_id}),
            "jobs": sorted(jobs, key=lambda j: -j["total_s"]),
            "islands": {k: islands[k] for k in sorted(islands)}}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--summarize", metavar="TRACE_JSONL", default=None,
                    help="print a JSON digest (per-job critical path, "
                         "per-island busy/blocked/idle) of a span JSONL "
                         "file written by --trace-out")
    ap.add_argument("--validate", metavar="TRACE_JSON", default=None,
                    help="schema-check a Chrome/Perfetto trace_event "
                         "export; exit 1 with the problem list if invalid")
    args = ap.parse_args(argv)
    if args.summarize:
        digest = summarize(load_jsonl(args.summarize))
        print(json.dumps(digest, indent=2))
        return 0
    if args.validate:
        with open(args.validate) as fh:
            errs = validate_chrome(json.load(fh))
        if errs:
            print("\n".join(errs), file=sys.stderr)
            return 1
        print(f"[obs.trace] {args.validate} is a valid trace_event export")
        return 0
    ap.error("pass --summarize or --validate")


if __name__ == "__main__":
    sys.exit(main())
